"""Mixture-of-Experts with real expert parallelism.

Two execution paths share one set of parameters:

* ``apply_moe_reference`` — pure jnp dense dispatch (every token through every
  expert, masked). O(E) FLOPs waste; used as the correctness oracle, for
  smoke tests, and for the tiny real-executor serving path.
* ``apply_moe_ep`` — shard_map expert parallelism. Experts are sharded over
  ``ep_axes`` (usually the whole mesh: 1–2 experts per chip for the 1T-class
  models, which cannot fit any replicated layout). Tokens are routed with a
  static-capacity all_to_all per mesh axis (composition of per-axis
  all_to_alls == full-mesh token exchange), computed, and routed back.

Layout inside the EP path
-------------------------
send/recv buffers are (N, L_e, C, d): N = #devices in the EP group,
L_e = experts per device (E padded to a multiple of N), C = per
(destination-device, local-expert) slot capacity. Tokens beyond capacity are
dropped (gates renormalised over the surviving top-k — standard GShard-style
drop). Because the buffer is bucketed per *local expert*, the expert GEMM is
a single batched einsum with zero masking waste.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from repro.models.layers import ModelConfig, _dense_init, _activate


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def physical_experts(cfg: ModelConfig) -> int:
    if cfg.expert_pad_to <= 0:
        return cfg.num_experts
    return math.ceil(cfg.num_experts / cfg.expert_pad_to) * cfg.expert_pad_to


def init_moe(rng, cfg: ModelConfig):
    k = jax.random.split(rng, 5)
    e, d, f = physical_experts(cfg), cfg.d_model, cfg.d_ff
    p = {
        "router": _dense_init(k[0], (d, cfg.num_experts), jnp.float32),
        "w_gate": _dense_init(k[1], (e, d, f), cfg.dtype),
        "w_up": _dense_init(k[2], (e, d, f), cfg.dtype),
        "w_down": _dense_init(k[3], (e, f, d), cfg.dtype, in_axis_size=f),
    }
    if cfg.num_shared_experts:
        ks = jax.random.split(k[4], 3)
        fs = cfg.d_ff * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": _dense_init(ks[0], (d, fs), cfg.dtype),
            "w_up": _dense_init(ks[1], (d, fs), cfg.dtype),
            "w_down": _dense_init(ks[2], (fs, d), cfg.dtype, in_axis_size=fs),
        }
    return p


# ---------------------------------------------------------------------------
# routing (shared by both paths)
# ---------------------------------------------------------------------------


def route(router_w, x, top_k: int, num_experts_padded: int):
    """x: (T, d) -> (gates (T,k) f32, expert_ids (T,k) i32).

    Padding experts (id >= real E) receive -inf logits and are never picked.
    """
    logits = x.astype(jnp.float32) @ router_w  # (T, E)
    e_real = logits.shape[-1]
    if num_experts_padded > e_real:
        pad = jnp.full((x.shape[0], num_experts_padded - e_real), -jnp.inf, jnp.float32)
        logits = jnp.concatenate([logits, pad], axis=-1)
    gate_vals, ids = lax.top_k(logits, top_k)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    return gates, ids


# ---------------------------------------------------------------------------
# reference path (oracle)
# ---------------------------------------------------------------------------


def apply_moe_reference(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d). Dense dispatch — every token through every expert."""
    b, s, d = x.shape
    e_phys = params["w_gate"].shape[0]
    xt = x.reshape(b * s, d)
    gates, ids = route(params["router"], xt, cfg.top_k, e_phys)
    # (T, E) combine weights
    combine = jnp.zeros((xt.shape[0], e_phys), jnp.float32)
    combine = combine.at[jnp.arange(xt.shape[0])[:, None], ids].add(gates)
    g = _activate(jnp.einsum("td,edf->tef", xt, params["w_gate"]), cfg.mlp_activation)
    u = jnp.einsum("td,edf->tef", xt, params["w_up"])
    y = jnp.einsum("tef,efd->ted", g * u, params["w_down"])
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), combine)
    out = out.astype(x.dtype).reshape(b, s, d)
    return out + _shared_expert(params, x, cfg)


def _shared_expert(params, x, cfg: ModelConfig):
    if "shared" not in params:
        return jnp.zeros_like(x)
    sp = params["shared"]
    g = _activate(jnp.einsum("bsd,df->bsf", x, sp["w_gate"]), cfg.mlp_activation)
    u = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
    return jnp.einsum("bsf,fd->bsd", g * u, sp["w_down"])


# ---------------------------------------------------------------------------
# EP path
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EPInfo:
    mesh: Mesh
    ep_axes: tuple[str, ...]      # axes experts are sharded over (in order)
    batch_axes: tuple[str, ...]   # axes the batch dim is sharded over
    seq_split_axis: str = "model"  # axis used to split tokens for routing
    capacity_factor: float = 2.0
    capacity_floor: int = 4       # min slots per (dst, local-expert) pair;
                                  # decode-batch hillclimb lever (§Perf)
    ep_mode: str = "alltoall"     # alltoall | allgather (tiny-batch decode:
                                  # broadcast tokens, compute local experts
                                  # masked, psum — moves O(T·d) instead of
                                  # O(N·C·d) padded buffers; §Perf)
    fused_a2a: bool = False       # single all_to_all over the whole EP
                                  # group instead of one per mesh axis
                                  # (halves dispatch wire volume; §Perf)

    @property
    def n_devices(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.ep_axes]))

    @property
    def seq_split(self) -> int:
        return int(self.mesh.shape[self.seq_split_axis])


def ep_padded_experts(num_experts: int, n_devices: int) -> tuple[int, int]:
    l_e = max(1, math.ceil(num_experts / n_devices))
    return l_e * n_devices, l_e


def _multi_axis_all_to_all(buf: jax.Array, info: EPInfo) -> jax.Array:
    """buf: (N, ...) where N = prod(ep_axes sizes), laid out so that the
    linear destination index is ``axis_index(ep_axes)`` (row-major over
    ep_axes).

    Fast path: one fused all_to_all over the whole EP group (named-axis
    tuple) — each element crosses the wire once. Fallback composes one
    tiled all_to_all per mesh axis, which moves the full buffer once *per
    axis* (†measured 2x wire volume on the kimi train cell — §Perf)."""
    if info.fused_a2a:
        try:
            return lax.all_to_all(buf, info.ep_axes, split_axis=0,
                                  concat_axis=0, tiled=True)
        except (TypeError, ValueError):
            pass
    sizes = [int(info.mesh.shape[a]) for a in info.ep_axes]
    rest = buf.shape[1:]
    x = buf.reshape(*sizes, *rest)
    for i, a in enumerate(info.ep_axes):
        x = lax.all_to_all(x, a, split_axis=i, concat_axis=i, tiled=True)
    return x.reshape(buf.shape)


def _dispatch_indices(ids, gates, l_e: int, n_dev: int, capacity: int):
    """Flatten (T,k) routing into send-buffer slots.

    Returns (slot (T*k,) int32 in [0, n_dev*l_e*capacity] — == size means
    dropped; flat buffer layout is (dst_dev, local_expert, capacity)).
    """
    tk = ids.shape[0] * ids.shape[1]
    flat_e = ids.reshape(tk)                      # global (padded) expert id
    bucket = flat_e                               # == dst*l_e + local_e
    order = jnp.argsort(bucket)                   # stable
    sorted_b = bucket[order]
    # rank within bucket: index - first-occurrence-index of this bucket value
    first = jnp.searchsorted(sorted_b, sorted_b, side="left")
    rank_sorted = jnp.arange(tk, dtype=jnp.int32) - first.astype(jnp.int32)
    rank = jnp.zeros((tk,), jnp.int32).at[order].set(rank_sorted)
    dropped = rank >= capacity
    slot = jnp.where(dropped, n_dev * l_e * capacity, bucket * capacity + rank)
    return slot.astype(jnp.int32), dropped


def apply_moe_ep(params, x: jax.Array, cfg: ModelConfig, info: EPInfo) -> jax.Array:
    """x: (B, S, d) — batch sharded over info.batch_axes, replicated over
    'model'. Output has the same layout."""
    mesh = info.mesh
    bspec = P(info.batch_axes, None, None)
    espec = P(info.ep_axes)  # leading (expert) dim over the whole EP group

    moe_params = {
        "router": params["router"],
        "w_gate": params["w_gate"],
        "w_up": params["w_up"],
        "w_down": params["w_down"],
    }
    pspecs = {
        "router": P(None, None),
        "w_gate": espec,
        "w_up": espec,
        "w_down": espec,
    }

    body = _moe_ep_allgather_local if info.ep_mode == "allgather" \
        else _moe_ep_local
    fn = shard_map(
        functools.partial(body, cfg=cfg, info=info),
        mesh=mesh,
        in_specs=(pspecs, bspec),
        out_specs=bspec,
        check_vma=False,
    )
    out = fn(moe_params, x)
    return out + _shared_expert(params, x, cfg)


def _moe_ep_allgather_local(p, x, *, cfg: ModelConfig, info: EPInfo):
    """Tiny-batch EP (decode): broadcast all tokens to every device,
    compute the local experts over all tokens with combine-weight masking,
    psum the contributions. Collective volume O(T·d) + O(T·d) — beats the
    all_to_all's O(N·C·d) padded buffers whenever T << N·C."""
    b_loc, s, d = x.shape
    n_dev = info.n_devices
    l_e = p["w_gate"].shape[0]
    e_pad = l_e * n_dev

    xt = x.reshape(b_loc * s, d)
    # gather every device's tokens (over the batch axes only — x is already
    # replicated over 'model'). Reversed order so the FIRST batch axis ends
    # up outermost, matching axis_index(batch_axes) row-major order.
    x_all = xt
    for a in reversed(info.batch_axes):
        x_all = lax.all_gather(x_all, a, axis=0, tiled=True)
    t_all = x_all.shape[0]

    gates, ids = route(p["router"], x_all, cfg.top_k, e_pad)    # (T, k)
    my_dev = lax.axis_index(info.ep_axes)
    # combine weight of each token for each LOCAL expert: (T, l_e)
    local_expert_ids = my_dev * l_e + jnp.arange(l_e)[None, :]   # (1, l_e)
    w = jnp.einsum(
        "tkl->tl",
        jnp.where(ids[:, :, None] == local_expert_ids[:, None, :],
                  gates[:, :, None], 0.0))

    h = jnp.broadcast_to(x_all[None], (l_e, t_all, d))
    g = _activate(jnp.einsum("etd,edf->etf", h, p["w_gate"]),
                  cfg.mlp_activation)
    u = jnp.einsum("etd,edf->etf", h, p["w_up"])
    y = jnp.einsum("etf,efd->etd", g * u, p["w_down"])          # (l_e, T, d)
    contrib = jnp.einsum("etd,te->td", y.astype(jnp.float32),
                         w.astype(jnp.float32))
    # sum expert contributions across the EP group
    out_all = lax.psum(contrib, info.ep_axes)                   # (T, d)
    # slice back this device's batch rows
    my_batch = lax.axis_index(info.batch_axes)
    t_loc = b_loc * s
    out = lax.dynamic_slice_in_dim(out_all, my_batch * t_loc, t_loc, axis=0)
    return out.astype(x.dtype).reshape(b_loc, s, d)


def _moe_ep_local(p, x, *, cfg: ModelConfig, info: EPInfo):
    """Per-device body. x: (B_loc, S, d) — identical copy on every member of
    the 'model' axis; each member routes a distinct 1/seq_split slice."""
    b_loc, s, d = x.shape
    n_dev = info.n_devices
    # experts-per-device from the actual shard_map slice: the physical
    # table is padded to a multiple of the EP group (expert_pad_to)
    l_e = p["w_gate"].shape[0]
    e_pad = l_e * n_dev
    assert e_pad >= cfg.num_experts, (
        f"padded expert table ({e_pad}) smaller than real experts "
        f"({cfg.num_experts}) — set ModelConfig.expert_pad_to for this mesh")
    sp = info.seq_split

    t_all = b_loc * s
    t_chunk = -(-t_all // sp)  # ceil
    xt = x.reshape(t_all, d)
    if t_chunk * sp != t_all:
        xt = jnp.pad(xt, ((0, t_chunk * sp - t_all), (0, 0)))
    m_idx = lax.axis_index(info.seq_split_axis)
    x_chunk = lax.dynamic_slice_in_dim(xt, m_idx * t_chunk, t_chunk, axis=0)

    gates, ids = route(p["router"], x_chunk, cfg.top_k, e_pad)  # (Tc,k)
    tk = t_chunk * cfg.top_k
    capacity = max(info.capacity_floor,
                   math.ceil(info.capacity_factor * tk / e_pad))

    slot, dropped = _dispatch_indices(ids, gates, l_e, n_dev, capacity)
    nslots = n_dev * l_e * capacity

    tok_idx = jnp.repeat(jnp.arange(t_chunk, dtype=jnp.int32), cfg.top_k)
    send = jnp.zeros((nslots, d), x.dtype).at[slot].set(
        x_chunk[tok_idx], mode="drop"
    )
    send = send.reshape(n_dev, l_e, capacity, d)
    recv = _multi_axis_all_to_all(send, info)          # (n_dev, l_e, C, d)

    # ---- local expert compute: (l_e, n_dev*C, d) batched GEMMs ----------
    h = recv.transpose(1, 0, 2, 3).reshape(l_e, n_dev * capacity, d)
    # local expert weights arrive sharded (l_e, d, f) per device
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    g = _activate(jnp.einsum("etd,edf->etf", h, wg), cfg.mlp_activation)
    u = jnp.einsum("etd,edf->etf", h, wu)
    y = jnp.einsum("etf,efd->etd", g * u, wd)          # (l_e, n_dev*C, d)

    back = y.reshape(l_e, n_dev, capacity, d).transpose(1, 0, 2, 3)
    ret = _multi_axis_all_to_all(back, info)           # (n_dev, l_e, C, d)
    ret = ret.reshape(nslots, d)

    # ---- combine: gather each assignment's output, weight by gate -------
    safe_slot = jnp.where(dropped, 0, slot)
    picked = ret[safe_slot].astype(jnp.float32)        # (T*k, d)
    w = jnp.where(dropped, 0.0, gates.reshape(tk))
    contrib = picked * w[:, None]
    out_chunk = jnp.zeros((t_chunk, d), jnp.float32).at[tok_idx].add(contrib)
    out_chunk = out_chunk.astype(x.dtype)

    # ---- reassemble the full token set (undo the model-axis seq split) --
    full = lax.all_gather(out_chunk, info.seq_split_axis, axis=0, tiled=True)
    return full[:t_all].reshape(b_loc, s, d)


def apply_moe(params, x, cfg: ModelConfig, ep: Optional[EPInfo] = None):
    if ep is None:
        return apply_moe_reference(params, x, cfg)
    return apply_moe_ep(params, x, cfg, ep)
