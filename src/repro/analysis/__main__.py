"""repro-lint CLI.

Usage:
    python -m repro.analysis                    # report all findings
    python -m repro.analysis --check            # gate vs the baseline
    python -m repro.analysis --write-baseline   # accept current findings

Exit contract (same as benchmarks/check_summary.py): 0 clean, 1 findings
(--check: *new* findings or *stale* baseline entries), 2 unreadable or
malformed input.

``--check`` is symmetric on purpose: a finding NOT in the baseline fails
(new violation), and a baseline entry with no matching finding also
fails (the violation was fixed — shrink the baseline in the same PR, so
it can only ever ratchet down).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import BASELINE_NAME, run_all
from repro.analysis.base import Project, dump_baseline, load_baseline


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: AST invariant checker "
                    "(determinism, SoA coherence, sync/donation, "
                    "parity surfaces, metrics schema, refusal context)")
    ap.add_argument("--root", default=".", metavar="DIR",
                    help="repository root to scan (default: cwd)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help=f"baseline file (default: <root>/{BASELINE_NAME})")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on findings not in the baseline, "
                         "or stale baseline entries")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings as the new baseline")
    args = ap.parse_args(argv)

    root = Path(args.root)
    if not root.is_dir():
        print(f"error: --root {root} is not a directory", file=sys.stderr)
        return 2
    try:
        project = Project.from_dir(root)
    except (OSError, SyntaxError) as e:
        print(f"error: cannot scan {root}: {e}", file=sys.stderr)
        return 2
    if not project.files:
        print(f"error: no sources found under {root} (wrong --root?)",
              file=sys.stderr)
        return 2

    findings = run_all(project)
    findings.extend(project.pragma_findings())
    findings.sort(key=lambda f: (f.path, f.line, f.pass_id, f.rule))

    baseline_path = Path(args.baseline) if args.baseline \
        else root / BASELINE_NAME

    if args.write_baseline:
        baseline_path.write_text(
            dump_baseline([f.fingerprint for f in findings]))
        print(f"wrote {len(findings)} fingerprint(s) to {baseline_path}")
        for f in findings:
            print("  " + f.render())
        return 0

    if not args.check:
        for f in findings:
            print(f.render())
        print(f"\n{len(findings)} finding(s) "
              f"({len(project.files)} files scanned)")
        return 1 if findings else 0

    # --check: diff against the committed baseline
    if baseline_path.exists():
        try:
            accepted = set(load_baseline(baseline_path))
        except (ValueError, OSError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
    else:
        accepted = set()

    fresh = {f.fingerprint: f for f in findings}
    new = [f for fp, f in fresh.items() if fp not in accepted]
    stale = sorted(accepted - set(fresh))

    for f in new:
        print("NEW  " + f.render())
    for fp in stale:
        print(f"STALE {fp}: baseline entry no longer fires "
              "(remove it — the baseline only ratchets down)")
    ok = len(findings) - len(new)
    print(f"\n{len(new)} new finding(s), {len(stale)} stale baseline "
          f"entr(y/ies), {ok} baselined, "
          f"{len(project.files)} files scanned")
    return 1 if new or stale else 0


if __name__ == "__main__":
    sys.exit(main())
