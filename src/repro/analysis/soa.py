"""Pass 2 — SoA-mirror coherence: no un-marked writes to mirrored state.

Two dirty-flag contracts keep the vectorized fast paths honest:

* ``ViewColumns`` mirrors ``WorkerView`` fields as numpy columns; every
  ``WorkerView`` field assignment goes through ``__setattr__``/``assign``
  which mark the row dirty. A write that *bypasses* them —
  ``object.__setattr__(view, "free_pages", ...)`` — silently desyncs the
  mirror and corrupts every batched dispatch decision until the next
  unrelated refresh. Such writes are only legal inside functions that
  mark the row dirty themselves (``_refresh_view_fast``-style).
* ``Worker.decode_running`` membership is mirrored by ``RequestColumns``
  and versioned by ``_batch_version``; a direct mutation that skips both
  lets ``complete_iteration`` apply vectorized effects to rows that are
  no longer the planned batch.

The mirrored-field set is derived from ``ViewColumns._pull`` in
``src/repro/core/toggle.py`` when the project contains it (adding a
column automatically extends enforcement); fixture projects without it
fall back to the pinned default list.
"""
from __future__ import annotations

import ast

from repro.analysis.base import Finding, Project, SourceFile, call_name, \
    dotted_name

PASS_ID = "soa"

SCOPE = ("src/repro/",)

#: (class, function) bodies that ARE the dirty-marking infrastructure
INFRA_SCOPES = frozenset({
    "WorkerView.__setattr__", "WorkerView.assign", "ViewColumns.__init__",
})

#: fallback when the project does not carry ViewColumns._pull
DEFAULT_MIRRORED_FIELDS = frozenset({
    "wid", "total_pages", "free_pages", "page_size", "decode_batch",
    "queued_prefill_tokens", "kv_used_tokens", "kv_capacity_tokens",
    "decode_sum_ctx", "min_tpot_slack", "speed", "alive",
})

#: canonical decode-batch mutators (they bump the version themselves)
BATCH_MUTATORS = frozenset({"_decode_add", "_decode_discard"})

MUTATING_DICT_METHODS = frozenset({
    "pop", "clear", "update", "setdefault", "popitem",
})


def _mirrored_fields(project: Project) -> frozenset[str]:
    """Field names ``ViewColumns._pull`` mirrors (``self.X[i] = ...``)."""
    for sf in project.iter_files(*SCOPE):
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.ClassDef) and node.name == "ViewColumns":
                for item in node.body:
                    if isinstance(item, ast.FunctionDef) \
                            and item.name == "_pull":
                        fields = set()
                        for stmt in ast.walk(item):
                            if isinstance(stmt, ast.Assign):
                                for t in stmt.targets:
                                    if isinstance(t, ast.Subscript) \
                                            and isinstance(t.value,
                                                           ast.Attribute):
                                        fields.add(t.value.attr)
                        if fields:
                            return frozenset(fields)
    return DEFAULT_MIRRORED_FIELDS


def _marks_dirty(func: ast.AST) -> bool:
    """Does this function body contain an explicit dirty-mark — a
    ``X.dirty.add(...)`` call or an assignment to ``X.dirty``?"""
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name.endswith(".dirty.add"):
                return True
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Attribute) and t.attr == "dirty":
                    return True
    return False


def _bumps_version_and_dirties(func: ast.AST) -> bool:
    """Does the function both bump ``_batch_version`` and write a
    ``_cols.dirty`` flag (the decode-batch membership contract)?"""
    bumped = dirtied = False
    for node in ast.walk(func):
        if isinstance(node, ast.AugAssign) \
                and isinstance(node.target, ast.Attribute) \
                and node.target.attr == "_batch_version":
            bumped = True
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Attribute):
                    if t.attr == "_batch_version":
                        bumped = True
                    if t.attr == "dirty" \
                            and dotted_name(t.value).endswith("_cols"):
                        dirtied = True
    return bumped and dirtied


class SoaCoherencePass:
    pass_id = PASS_ID

    def run(self, project: Project) -> list[Finding]:
        mirrored = _mirrored_fields(project)
        out: list[Finding] = []
        for sf in project.iter_files(*SCOPE):
            out.extend(self._check_file(sf, mirrored))
        return out

    def _check_file(self, sf: SourceFile,
                    mirrored: frozenset[str]) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call) \
                    and call_name(node) == "object.__setattr__":
                out.extend(self._check_bypass(sf, node, mirrored))
            else:
                out.extend(self._check_decode_mutation(sf, node))
        return out

    # ------------------------------------------------- object.__setattr__
    def _check_bypass(self, sf: SourceFile, node: ast.Call,
                      mirrored: frozenset[str]) -> list[Finding]:
        scope = sf.scope(node)
        if scope in INFRA_SCOPES:
            return []
        if sf.has_pragma(node, "allow-direct-write"):
            return []
        # which attribute is written? literal second arg when present
        attr = None
        if len(node.args) >= 2 and isinstance(node.args[1], ast.Constant) \
                and isinstance(node.args[1].value, str):
            attr = node.args[1].value
        if attr is not None and attr not in mirrored:
            # private plumbing (_row/_cols) or an unrelated class's
            # frozen-dataclass init — not a mirrored field, no hazard
            return []
        func = sf.enclosing_function(node)
        if func is not None and (_marks_dirty(func)
                                 or sf.has_pragma(func, "allow-direct-write")):
            return []
        what = f"field {attr!r}" if attr else "a dynamically-named field"
        return [Finding(
            PASS_ID, "bypass-setattr", sf.path, node.lineno,
            f"object.__setattr__ writes mirrored {what} without marking "
            "the ViewColumns row dirty; assign through the view (or mark "
            "`<cols>.dirty` in this function)", scope)]

    # --------------------------------------------------- decode_running
    def _check_decode_mutation(self, sf: SourceFile,
                               node: ast.AST) -> list[Finding]:
        hit_line = None
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Subscript) \
                        and dotted_name(t.value).endswith("decode_running"):
                    hit_line = node.lineno
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                if isinstance(t, ast.Subscript) \
                        and dotted_name(t.value).endswith("decode_running"):
                    hit_line = node.lineno
        elif isinstance(node, ast.Call):
            name = dotted_name(node.func)
            parts = name.split(".")
            if len(parts) >= 2 and parts[-1] in MUTATING_DICT_METHODS \
                    and parts[-2] == "decode_running":
                hit_line = node.lineno
        if hit_line is None:
            return []
        if sf.has_pragma(node, "allow-direct-write"):
            return []
        func = sf.enclosing_function(node)
        if func is not None:
            if func.name in BATCH_MUTATORS:
                return []
            if _bumps_version_and_dirties(func) \
                    or sf.has_pragma(func, "allow-direct-write"):
                return []
        return [Finding(
            PASS_ID, "decode-batch-version", sf.path, hit_line,
            "decode_running mutated without bumping _batch_version and "
            "re-dirtying the RequestColumns mirror; use _decode_add/"
            "_decode_discard (or bump both in this function)",
            sf.scope(node))]
