"""Shared infrastructure for the repro-lint passes.

``Project`` holds every scanned file (source text + parsed AST + pragma
map + enclosing-scope index); passes are pure functions of a Project, so
the fixture tests feed in-memory snippets through exactly the code path
the CLI drives over the real tree.

Pragma grammar (one per physical line, attached to that line; for
multi-line statements any line the statement spans counts; for ``def``
nodes the def line itself):

    # lint: <name>(<reason or argument>)

Every pragma requires a non-empty argument — an exemption without a
recorded reason is itself a finding (``pragma-reason``).
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Iterable, Optional

PRAGMA_RE = re.compile(r"#\s*lint:\s*([a-z][a-z0-9-]*)\(([^)]*)\)")

#: pragma names the tool understands; anything else is reported, so a
#: typo'd exemption can never silently grant itself
KNOWN_PRAGMAS = frozenset({
    "allow-wallclock", "allow-rng", "allow-set-iter", "allow-direct-write",
    "allow-sync", "allow-raise", "allow-key",
    "parity-ref", "not-parity", "parity-test", "sync-budget",
})


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation. The fingerprint intentionally excludes
    the line number (pure code motion must not churn the baseline) and
    keys on the enclosing scope instead."""
    pass_id: str
    rule: str
    path: str          # repo-relative posix path
    line: int
    message: str
    scope: str = ""    # enclosing Class.function qualname ("" = module)

    @property
    def fingerprint(self) -> str:
        where = self.scope or f"L{self.line}"
        return f"{self.pass_id}:{self.rule}:{self.path}:{where}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.pass_id}/{self.rule}] " \
               f"{self.message}"


class SourceFile:
    """One parsed file: text, AST, per-line pragmas, scope index."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.tree = ast.parse(text, filename=path)
        # line -> [(pragma, argument)]
        self.pragmas: dict[int, list[tuple[str, str]]] = {}
        for i, line in enumerate(text.splitlines(), start=1):
            for m in PRAGMA_RE.finditer(line):
                self.pragmas.setdefault(i, []).append(
                    (m.group(1), m.group(2).strip()))
        # node -> enclosing (class_stack, func_stack) qualname
        self._scope_of: dict[ast.AST, str] = {}
        self._parent: dict[ast.AST, ast.AST] = {}
        self._index_scopes()

    def _index_scopes(self) -> None:
        def walk(node: ast.AST, stack: tuple[str, ...]) -> None:
            for child in ast.iter_child_nodes(node):
                self._parent[child] = node
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                    self._scope_of[child] = ".".join(stack) or ""
                    walk(child, stack + (child.name,))
                else:
                    self._scope_of[child] = ".".join(stack) or ""
                    walk(child, stack)
        walk(self.tree, ())

    def scope(self, node: ast.AST) -> str:
        """``Class.method`` qualname enclosing ``node`` ("" at module
        level). For def/class nodes this is the scope they are DEFINED
        in, not their own name."""
        return self._scope_of.get(node, "")

    def qualname(self, node) -> str:
        """Scope *of* a def node including its own name."""
        outer = self.scope(node)
        return f"{outer}.{node.name}" if outer else node.name

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parent.get(node)

    def enclosing_function(self, node: ast.AST):
        cur = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self._parent.get(cur)
        return None

    # ------------------------------------------------------------- pragmas
    def pragma_arg(self, node: ast.AST, name: str) -> Optional[str]:
        """Argument of pragma ``name`` if present on any line ``node``
        spans (None = absent; "" = present but reason-less)."""
        lo = getattr(node, "lineno", None)
        if lo is None:
            return None
        hi = getattr(node, "end_lineno", lo) or lo
        for ln in range(lo, hi + 1):
            for pname, arg in self.pragmas.get(ln, ()):
                if pname == name:
                    return arg
        return None

    def has_pragma(self, node: ast.AST, name: str) -> bool:
        return self.pragma_arg(node, name) is not None


class Project:
    """Every file the suite looks at, keyed by repo-relative posix path.

    ``files`` covers linted + cross-referenced sources (src, benchmarks,
    examples, tests); ``data`` carries non-Python inputs (the committed
    BENCH summary) as raw text.
    """

    SCAN_GLOBS = ("src/repro/**/*.py", "benchmarks/*.py", "examples/*.py",
                  "tests/*.py")
    DATA_FILES = ("BENCH_summary.json",)

    def __init__(self, files: dict[str, SourceFile],
                 data: Optional[dict[str, str]] = None,
                 root: Optional[Path] = None):
        self.files = files
        self.data = data or {}
        self.root = root

    @classmethod
    def from_dir(cls, root: Path | str) -> "Project":
        root = Path(root)
        files: dict[str, SourceFile] = {}
        for pattern in cls.SCAN_GLOBS:
            for p in sorted(root.glob(pattern)):
                rel = p.relative_to(root).as_posix()
                if "__pycache__" in rel:
                    continue
                files[rel] = SourceFile(rel, p.read_text())
        data = {}
        for name in cls.DATA_FILES:
            p = root / name
            if p.exists():
                data[name] = p.read_text()
        return cls(files, data, root=root)

    @classmethod
    def from_sources(cls, sources: dict[str, str],
                     data: Optional[dict[str, str]] = None) -> "Project":
        return cls({path: SourceFile(path, text)
                    for path, text in sources.items()}, data)

    def iter_files(self, *prefixes: str) -> Iterable[SourceFile]:
        for path in sorted(self.files):
            if not prefixes or any(path.startswith(p) for p in prefixes):
                yield self.files[path]

    def pragma_findings(self, pass_id: str = "pragma") -> list[Finding]:
        """Unknown pragma names and reason-less pragmas, project-wide."""
        out = []
        for sf in self.iter_files():
            if sf.path.startswith("tests/"):
                continue
            for line, entries in sorted(sf.pragmas.items()):
                for name, arg in entries:
                    if name not in KNOWN_PRAGMAS:
                        out.append(Finding(
                            pass_id, "unknown-pragma", sf.path, line,
                            f"unknown lint pragma {name!r} (known: "
                            f"{', '.join(sorted(KNOWN_PRAGMAS))})"))
                    elif not arg:
                        out.append(Finding(
                            pass_id, "pragma-reason", sf.path, line,
                            f"pragma {name!r} needs a non-empty reason/"
                            f"argument"))
        return out


# ---------------------------------------------------------------- helpers
def dotted_name(node: ast.AST) -> str:
    """``a.b.c`` for Name/Attribute chains, "" for anything else."""
    parts: list[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return ""


def call_name(call: ast.Call) -> str:
    return dotted_name(call.func)


def load_baseline(path: Path) -> list[str]:
    """Fingerprint list from a baseline file. Raises ValueError on a
    malformed document (the CLI maps that to exit 2)."""
    doc = json.loads(path.read_text())
    if not isinstance(doc, dict) or "findings" not in doc \
            or not isinstance(doc["findings"], list):
        raise ValueError(f"{path}: not a repro-lint baseline "
                         "(need a dict with a 'findings' list)")
    return [str(f) for f in doc["findings"]]


def dump_baseline(fingerprints: list[str]) -> str:
    return json.dumps({"schema_version": 1,
                       "findings": sorted(fingerprints)}, indent=1) + "\n"
