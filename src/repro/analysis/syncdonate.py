"""Pass 3 — host-sync & donation discipline in the real executor.

The batched executor fast path (PR 9) earns its ~150x by composing a
whole iteration on device and paying exactly ONE ``block_until_ready``
and ONE device->host transfer at the end. A stray ``.item()`` or
``np.asarray`` inside the composition silently serialises the pipeline
— wall-clock regresses but nothing *fails* until the weekly profile run.
This pass makes the budget structural:

* ``sync-budget`` — fast-path scopes (the pinned ``FAST_SCOPES``
  registry plus any def carrying ``# lint: sync-budget(block=N,host=M)``)
  may not exceed their budget of ``jax.block_until_ready`` /
  ``jax.device_get`` / ``.item()`` / ``np.asarray`` call sites.
  Branches of a conditional count as alternatives (max, not sum);
  a sync inside a loop is unconditionally over budget.
* ``missing-fast-path`` — a registry scope that disappears (rename)
  is reported rather than silently un-checked.
* ``use-after-donate`` — a buffer passed at a ``donate_argnums``
  position of a jitted entry point is dead after the call; reading it
  again is undefined behaviour on accelerators (and only *works* on CPU
  because CPU jax ignores donation). The donated-entry registry is
  derived from the module's own ``jax.jit(..., donate_argnums=...)``
  sites, so new kernels are covered automatically.
"""
from __future__ import annotations

import ast
import math
import re

from repro.analysis.base import Finding, Project, SourceFile, dotted_name

PASS_ID = "sync"

SCOPE_SUFFIX = "serving/executor.py"

#: pinned fast-path scopes: function name -> (block budget, host budget).
#: ``warmup`` composes the whole compile grid before its single sync.
FAST_SCOPES = {
    "_run_plan_fast": (1, 1),
    "warmup": (1, 0),
}

SYNC_BLOCK_CALLS = frozenset({"jax.block_until_ready"})
SYNC_HOST_CALLS = frozenset({"np.asarray", "numpy.asarray",
                             "jax.device_get"})
BUDGET_RE = re.compile(r"block\s*=\s*(\d+)\s*,\s*host\s*=\s*(\d+)")

#: statements that merely *contain* other statements — a call site is
#: attributed to its innermost simple statement, never to these
_COMPOUND_STMTS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef,
                   ast.For, ast.AsyncFor, ast.While, ast.If, ast.With,
                   ast.AsyncWith, ast.Try)


class _SyncCounter:
    """Branch-aware sync-site counter: If/IfExp branches are
    alternatives (max), loop bodies are unbounded (inf)."""

    def __init__(self, sf: SourceFile):
        self.sf = sf

    def count(self, node: ast.AST) -> tuple[float, float]:
        if isinstance(node, (ast.If,)):
            t = self.count_all(node.test)
            body = self.count_seq(node.body)
            orelse = self.count_seq(node.orelse)
            return (t[0] + max(body[0], orelse[0]),
                    t[1] + max(body[1], orelse[1]))
        if isinstance(node, ast.IfExp):
            t = self.count(node.test)
            b = self.count(node.body)
            o = self.count(node.orelse)
            return (t[0] + max(b[0], o[0]), t[1] + max(b[1], o[1]))
        if isinstance(node, (ast.For, ast.AsyncFor, ast.While)):
            it = self.count_seq([node.iter] if hasattr(node, "iter") else
                                [node.test])
            body = self.count_seq(node.body + node.orelse)
            if body[0] or body[1]:
                # any sync under a loop blows a per-iteration budget
                return (it[0] + (math.inf if body[0] else 0),
                        it[1] + (math.inf if body[1] else 0))
            return it
        block = host = 0.0
        if isinstance(node, ast.Call):
            if not self.sf.has_pragma(node, "allow-sync"):
                name = dotted_name(node.func)
                if name in SYNC_BLOCK_CALLS:
                    block += 1
                elif name in SYNC_HOST_CALLS:
                    host += 1
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" and not node.args:
                    host += 1
        b, h = self.count_seq(list(ast.iter_child_nodes(node)))
        return (block + b, host + h)

    def count_seq(self, nodes) -> tuple[float, float]:
        block = host = 0.0
        for n in nodes:
            b, h = self.count(n)
            block += b
            host += h
        return (block, host)

    def count_all(self, node: ast.AST) -> tuple[float, float]:
        return self.count(node)


def _donated_entries(sf: SourceFile) -> dict[str, tuple[int, ...]]:
    """Entry-point name -> donated positional indices, derived from
    ``jax.jit(..., donate_argnums=...)`` sites: keyed by the enclosing
    def (factory/property pattern) and, when the jit result is assigned
    to ``self.X``, by ``X`` as well."""
    entries: dict[str, tuple[int, ...]] = {}
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call)
                and dotted_name(node.func) == "jax.jit"):
            continue
        donate: tuple[int, ...] = ()
        for kw in node.keywords:
            if kw.arg == "donate_argnums":
                if isinstance(kw.value, ast.Constant) \
                        and isinstance(kw.value.value, int):
                    donate = (kw.value.value,)
                elif isinstance(kw.value, (ast.Tuple, ast.List)):
                    donate = tuple(
                        e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int))
        if not donate:
            continue
        func = sf.enclosing_function(node)
        if func is not None:
            entries[func.name] = donate
        parent = sf.parent(node)
        if isinstance(parent, ast.Assign):
            for t in parent.targets:
                if isinstance(t, ast.Attribute):
                    entries[t.attr] = donate
    return entries


def _donated_call(sf: SourceFile, node: ast.Call,
                  entries: dict[str, tuple[int, ...]]):
    """(donated indices, args) when ``node`` invokes a donated entry:
    either ``obj.entry(args)`` directly or ``obj.factory(...)()`` for
    the factory pattern. A factory's *own* arguments (``prefill_fn(b, 1)``
    inside ``prefill_fn(b, 1)(params, cache, ...)``) are selectors, not
    donated buffers, so a call that is itself immediately called does
    not match the direct form."""
    f = node.func
    parent = sf.parent(node)
    immediately_called = isinstance(parent, ast.Call) and parent.func is node
    if isinstance(f, ast.Attribute) and f.attr in entries \
            and not immediately_called:
        return entries[f.attr], node.args
    if isinstance(f, ast.Call) and isinstance(f.func, ast.Attribute) \
            and f.func.attr in entries:
        return entries[f.func.attr], node.args
    return None, None


class SyncDonationPass:
    pass_id = PASS_ID

    def run(self, project: Project) -> list[Finding]:
        out: list[Finding] = []
        for sf in project.iter_files("src/repro/"):
            if not sf.path.endswith(SCOPE_SUFFIX):
                continue
            out.extend(self._check_budgets(sf))
            out.extend(self._check_donation(sf))
        return out

    # ------------------------------------------------------- sync budgets
    def _check_budgets(self, sf: SourceFile) -> list[Finding]:
        out: list[Finding] = []
        seen: set[str] = set()
        counter = _SyncCounter(sf)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            budget = FAST_SCOPES.get(node.name)
            arg = sf.pragma_arg(node, "sync-budget")
            if arg:
                m = BUDGET_RE.search(arg)
                if m:
                    budget = (int(m.group(1)), int(m.group(2)))
            if budget is None:
                continue
            seen.add(node.name)
            block, host = counter.count_seq(node.body)
            if block > budget[0]:
                out.append(Finding(
                    PASS_ID, "sync-budget", sf.path, node.lineno,
                    f"{node.name} issues {self._fmt(block)} "
                    f"block_until_ready sync(s); fast-path budget is "
                    f"{budget[0]} per iteration", sf.qualname(node)))
            if host > budget[1]:
                out.append(Finding(
                    PASS_ID, "sync-budget", sf.path, node.lineno,
                    f"{node.name} issues {self._fmt(host)} device->host "
                    f"transfer(s) (np.asarray/.item()/device_get); "
                    f"fast-path budget is {budget[1]} per iteration",
                    sf.qualname(node)))
        for name in FAST_SCOPES:
            if name not in seen:
                out.append(Finding(
                    PASS_ID, "missing-fast-path", sf.path, 1,
                    f"pinned fast-path scope {name!r} not found in "
                    f"{sf.path}; update the FAST_SCOPES registry in "
                    "repro.analysis.syncdonate alongside the rename",
                    name))
        return out

    @staticmethod
    def _fmt(n: float) -> str:
        return "loop-many" if math.isinf(n) else str(int(n))

    # ---------------------------------------------------------- donation
    def _check_donation(self, sf: SourceFile) -> list[Finding]:
        entries = _donated_entries(sf)
        if not entries:
            return []
        out: list[Finding] = []
        for func in ast.walk(sf.tree):
            if not isinstance(func, ast.FunctionDef):
                continue
            # leaf statements of THIS function in source order (nested
            # defs excluded — a nested jit body is the *implementation*,
            # not a caller; compound statements excluded — each call
            # belongs to its innermost simple statement)
            stmts = [s for s in ast.walk(func)
                     if isinstance(s, ast.stmt)
                     and not isinstance(s, _COMPOUND_STMTS)
                     and sf.enclosing_function(s) is func]
            stmts.sort(key=lambda s: s.lineno)
            for stmt in stmts:
                for call in ast.walk(stmt):
                    if not isinstance(call, ast.Call):
                        continue
                    donate, args = _donated_call(sf, call, entries)
                    if donate is None:
                        continue
                    for idx in donate:
                        if idx >= len(args):
                            continue
                        expr = ast.unparse(args[idx])
                        if self._rebound_by(stmt, expr):
                            continue
                        use = self._later_use(stmts, stmt, expr)
                        if use is not None \
                                and not sf.has_pragma(stmt, "allow-sync"):
                            out.append(Finding(
                                PASS_ID, "use-after-donate", sf.path, use,
                                f"{expr!r} was donated at line "
                                f"{call.lineno} (donate_argnums) and read "
                                "again without rebinding; donated buffers "
                                "are dead after the call", sf.qualname(func)))
        return out

    @staticmethod
    def _rebound_by(stmt: ast.stmt, expr: str) -> bool:
        """Does the statement assign the call result back over ``expr``
        (the ``x = f(x)`` donation idiom)?"""
        if not isinstance(stmt, ast.Assign):
            return False
        for t in stmt.targets:
            elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t]
            for e in elts:
                if ast.unparse(e) == expr:
                    return True
        return False

    @staticmethod
    def _later_use(stmts, stmt: ast.stmt, expr: str):
        """First line after ``stmt`` that reads ``expr`` before any
        rebinding assignment to it; None when the buffer is never
        touched again."""
        after = [s for s in stmts if s.lineno > stmt.lineno]
        for s in after:
            if SyncDonationPass._rebound_by(s, expr):
                return None
            for sub in ast.walk(s):
                if isinstance(sub, (ast.Name, ast.Attribute)) \
                        and isinstance(getattr(sub, "ctx", None), ast.Load) \
                        and ast.unparse(sub) == expr:
                    return s.lineno
        return None
