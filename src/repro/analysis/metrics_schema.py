"""Pass 5 — metrics-schema classification: no key dodges the perf gate.

``benchmarks/check_summary.py`` gates regressions per key *class*
(exact / latency / throughput / attainment); a key that classifies as
"info" is printed but never gated — a new headline number with an
unrecognised name silently opts out of CI. This pass closes the loop:

* ``unclassified-key``  — every key in the committed
  ``BENCH_summary.json`` must classify under a gating class (mirrors
  ``check_summary.classify`` exactly, including the numeric-in-[0,1]
  attainment heuristic, against the snapshot's own values).
* ``unclassified-emit`` — every key emission site in ``benchmarks/``
  (``summary["k"] = ...``, ``summary.update(k=...)``, and the literal
  keys of the ``summary = {...}`` seed dict) must classify *statically*
  — by ``EXACT_KEYS`` membership or a recognised suffix
  (``_s``/``_ms``/``_rps``/``_speedup``/``_attainment``/``_rate``/
  ``_abs_err``) — because at emission time there is no value for the
  [0,1] heuristic to inspect. Deliberately-informational keys carry
  ``# lint: allow-key(<key>: reason)``.
* ``emitted-not-in-snapshot`` — a statically-emitted key missing from
  the committed snapshot means the snapshot is stale (the perf gate
  would fail the same way at bench time; this catches it at lint time).

``EXACT_KEYS`` is read out of ``check_summary.py``'s AST so the two
tools can never drift apart; fixture projects without that file fall
back to the pinned default.
"""
from __future__ import annotations

import ast
import json

from repro.analysis.base import Finding, Project

PASS_ID = "metrics"

CHECKER_PATH = "benchmarks/check_summary.py"
SNAPSHOT_NAME = "BENCH_summary.json"

DEFAULT_EXACT_KEYS = frozenset({
    "schema_version", "ref_rate", "n_requests", "generator",
})

LATENCY_SUFFIXES = ("_s", "_ms")
THROUGHPUT_SUFFIXES = ("_rps", "_speedup")
#: suffixes that *name* an attainment-class fraction, so an emission
#: site classifies without needing a runtime value
ATTAINMENT_SUFFIXES = ("_attainment", "_rate", "_abs_err")


def _exact_keys(project: Project) -> frozenset[str]:
    sf = project.files.get(CHECKER_PATH)
    if sf is None:
        return DEFAULT_EXACT_KEYS
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "EXACT_KEYS"
                        for t in node.targets) \
                and isinstance(node.value, ast.Set):
            keys = {e.value for e in node.value.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str)}
            if keys:
                return frozenset(keys)
    return DEFAULT_EXACT_KEYS


def classify_static(key: str, exact: frozenset[str]) -> str:
    """Value-free mirror of ``check_summary.classify`` (suffix rules in
    the same precedence order), with the attainment name-suffixes
    standing in for the runtime [0,1] check."""
    if key in exact:
        return "exact"
    if key.endswith(LATENCY_SUFFIXES):
        return "latency"
    if key.endswith(THROUGHPUT_SUFFIXES):
        return "throughput"
    if key.endswith(ATTAINMENT_SUFFIXES):
        return "attainment"
    return "info"


def classify_value(key: str, value, exact: frozenset[str]) -> str:
    """Mirror of ``check_summary.classify`` for keys with a value."""
    if key in exact:
        return "exact"
    if key.endswith(LATENCY_SUFFIXES):
        return "latency"
    if key.endswith(THROUGHPUT_SUFFIXES):
        return "throughput"
    if isinstance(value, (int, float)) and not isinstance(value, bool) \
            and 0.0 <= float(value) <= 1.0:
        return "attainment"
    return "info"


class MetricsSchemaPass:
    pass_id = PASS_ID

    def run(self, project: Project) -> list[Finding]:
        exact = _exact_keys(project)
        allowed = self._allowed_keys(project)
        out: list[Finding] = []
        snapshot = self._snapshot(project, out)
        if snapshot is not None:
            for key in sorted(snapshot):
                if key in allowed:
                    continue
                if classify_value(key, snapshot[key], exact) == "info":
                    out.append(Finding(
                        PASS_ID, "unclassified-key", SNAPSHOT_NAME, 1,
                        f"summary key {key!r} classifies as 'info' in "
                        "check_summary.py — it is printed but never "
                        "gated; rename it into a gated class, add it to "
                        "EXACT_KEYS, or annotate its emission with "
                        "`# lint: allow-key({key}: reason)`".format(key=key),
                        key))
        for sf, key, line in self._emissions(project):
            if self._line_allowed(sf, line):
                continue
            if key in allowed:
                continue
            if classify_static(key, exact) == "info":
                out.append(Finding(
                    PASS_ID, "unclassified-emit", sf.path, line,
                    f"emitted summary key {key!r} has no gating class "
                    "(not in EXACT_KEYS, no recognised suffix); the perf "
                    "gate will never check it", key))
            if snapshot is not None and key not in snapshot:
                out.append(Finding(
                    PASS_ID, "emitted-not-in-snapshot", sf.path, line,
                    f"summary key {key!r} is emitted here but absent from "
                    f"the committed {SNAPSHOT_NAME}; regenerate the "
                    "snapshot in this PR", key))
        return out

    # ----------------------------------------------------------- helpers
    @staticmethod
    def _line_allowed(sf, line: int) -> bool:
        return any(name == "allow-key"
                   for name, _ in sf.pragmas.get(line, ()))

    @staticmethod
    def _allowed_keys(project: Project) -> set[str]:
        """Key names granted 'info' status via ``allow-key(<key>: why)``
        pragmas anywhere in benchmarks/ sources."""
        allowed: set[str] = set()
        for sf in project.iter_files("benchmarks/"):
            for entries in sf.pragmas.values():
                for name, arg in entries:
                    if name == "allow-key" and arg:
                        allowed.add(arg.split(":")[0].strip())
        return allowed

    @staticmethod
    def _snapshot(project: Project, out: list[Finding]):
        raw = project.data.get(SNAPSHOT_NAME)
        if raw is None:
            return None
        try:
            doc = json.loads(raw)
        except json.JSONDecodeError as e:
            out.append(Finding(
                PASS_ID, "snapshot-unreadable", SNAPSHOT_NAME, 1,
                f"committed summary is not valid JSON: {e}"))
            return None
        if not isinstance(doc, dict):
            out.append(Finding(
                PASS_ID, "snapshot-unreadable", SNAPSHOT_NAME, 1,
                "committed summary is not a JSON object"))
            return None
        return doc

    @staticmethod
    def _emissions(project: Project):
        """(file, key, line) for every static summary-key emission in
        benchmarks/: subscript assigns, .update(kw=...), and the seed
        dict literal — all keyed off a variable literally named
        ``summary``."""
        for sf in project.iter_files("benchmarks/"):
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Subscript) \
                                and isinstance(t.value, ast.Name) \
                                and t.value.id == "summary" \
                                and isinstance(t.slice, ast.Constant) \
                                and isinstance(t.slice.value, str):
                            yield sf, t.slice.value, t.lineno
                        elif isinstance(t, ast.Name) and t.id == "summary" \
                                and isinstance(node.value, ast.Dict):
                            for k in node.value.keys:
                                if isinstance(k, ast.Constant) \
                                        and isinstance(k.value, str):
                                    yield sf, k.value, k.lineno
                elif isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "update" \
                        and isinstance(node.func.value, ast.Name) \
                        and node.func.value.id == "summary":
                    for kw in node.keywords:
                        if kw.arg is not None:
                            yield sf, kw.arg, kw.value.lineno
