"""repro-lint: AST-based invariant checker for this repo's correctness
contracts.

The scheduler/engine/executor fast paths (PRs 7-9) are guarded by
after-the-fact parity tests; this package makes the underlying
*invariants* machine-checked on every push:

* ``determinism``  — no wall-clock, unseeded RNG, or set-iteration-order
  hazards on the sim/decision path (``sched/``, ``serving/``, ``core/``,
  ``workload/``) or in ``benchmarks/``/``examples/``;
* ``soa``          — every write to a ``WorkerView``-mirrored field flows
  through the dirty-marking setters, and every direct
  ``decode_running`` mutation bumps ``_batch_version`` + re-dirties the
  ``RequestColumns`` mirror;
* ``sync``         — the real-executor fast path stays within its
  documented one-``block_until_ready`` / one-host-transfer budget, and
  buffers passed through ``donate_argnums`` are never read after
  donation;
* ``parity``       — every ``*_vec``/``*_batch``/``*_fast`` fast path
  declares a scalar reference and is reachable from a test-exercised
  entry point;
* ``metrics``      — every ``BENCH_summary.json`` key classifies under
  exactly one ``check_summary.py`` gating class, so a new key can never
  silently dodge the perf gate;
* ``refusals``     — typed refusals (``SlotExhausted``) carry their full
  ``(wid, rid, limit)`` context, and refusal-class exceptions are never
  raised bare.

CLI: ``python -m repro.analysis [--check] [--write-baseline]`` with the
``check_summary.py`` exit contract (0 clean, 1 findings, 2 bad input).
Pragmas (``# lint: allow-wallclock(reason)`` style) grant per-line or
per-def exemptions; every pragma requires a non-empty reason. The
committed baseline (``LINT_baseline.json``) records accepted pre-existing
findings — kept empty by fixing violations instead of baselining them.
"""
from __future__ import annotations

from repro.analysis.base import Finding, Project, load_baseline
from repro.analysis.determinism import DeterminismPass
from repro.analysis.metrics_schema import MetricsSchemaPass
from repro.analysis.parity import ParityPass
from repro.analysis.refusals import RefusalsPass
from repro.analysis.soa import SoaCoherencePass
from repro.analysis.syncdonate import SyncDonationPass

#: the pass suite, in report order
PASSES = (
    DeterminismPass,
    SoaCoherencePass,
    SyncDonationPass,
    ParityPass,
    MetricsSchemaPass,
    RefusalsPass,
)

BASELINE_NAME = "LINT_baseline.json"


def run_all(project: Project, passes=PASSES) -> list[Finding]:
    """Run every pass over ``project``; deterministically ordered output."""
    findings: list[Finding] = []
    for cls in passes:
        findings.extend(cls().run(project))
    findings.sort(key=lambda f: (f.path, f.line, f.pass_id, f.rule))
    return findings


__all__ = [
    "Finding", "Project", "PASSES", "BASELINE_NAME", "run_all",
    "load_baseline",
]
