"""Pass 4 — parity-surface registry: every fast path has a scalar
reference and a test that exercises it.

The repo's perf story rests on bit-for-bit parity between vectorized
fast paths and their scalar references (``vectorized=False`` /
``batched=False`` are the seed-pinned baselines). A fast path without a
declared reference (or whose reference silently vanished in a refactor)
has nothing to be parity-tested *against*; a fast path no test can reach
is parity-tested against nothing.

Detection: every ``FunctionDef`` under the decision/perf packages whose
name ends in ``_vec``/``_batch``/``_fast`` is a parity surface. For each:

* ``no-scalar-ref`` — there must be a def named after the stripped base
  (``_chunk_for_vec`` -> ``_chunk_for`` or public ``chunk_for``) in the
  same module, or anywhere in scope; a surface whose reference lives
  under a different name declares it with ``# lint: parity-ref(name)``.
  Helpers that merely *sound* vectorized opt out with
  ``# lint: not-parity(reason)``.
* ``no-parity-test`` — the surface must be reachable from test code:
  its name appears in ``tests/``, or some (transitive) caller's name
  does (call graph by simple name over the scanned sources — an e2e
  decision-parity test that drives ``handle_batch`` covers everything
  the batch path calls). ``# lint: parity-test(tests/test_x.py)``
  pins an explicit test module instead.
"""
from __future__ import annotations

import ast

from repro.analysis.base import Finding, Project, SourceFile

PASS_ID = "parity"

SCOPE = ("src/repro/core/", "src/repro/serving/", "src/repro/sched/",
         "src/repro/perf/", "src/repro/workload/")

SUFFIXES = ("_vec", "_batch", "_fast")


def _base_candidates(name: str) -> list[str]:
    for suf in SUFFIXES:
        if name.endswith(suf):
            base = name[: -len(suf)]
            cands = [base]
            if base.startswith("_"):
                cands.append(base.lstrip("_"))
            return [c for c in cands if c]
    return []


class ParityPass:
    pass_id = PASS_ID

    def run(self, project: Project) -> list[Finding]:
        # every def in scope, by simple name -> set of defining files
        defs: dict[str, set[str]] = {}
        per_file_defs: dict[str, set[str]] = {}
        surfaces: list[tuple[SourceFile, ast.FunctionDef]] = []
        callees: dict[str, set[str]] = {}   # def name -> called names
        for sf in project.iter_files(*SCOPE):
            names = per_file_defs.setdefault(sf.path, set())
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.FunctionDef):
                    continue
                defs.setdefault(node.name, set()).add(sf.path)
                names.add(node.name)
                called = callees.setdefault(node.name, set())
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Call):
                        f = sub.func
                        if isinstance(f, ast.Attribute):
                            called.add(f.attr)
                        elif isinstance(f, ast.Name):
                            called.add(f.id)
                if any(node.name.endswith(s) for s in SUFFIXES) \
                        and not node.name.startswith("__"):
                    surfaces.append((sf, node))

        test_text = "\n".join(sf.text for sf in project.iter_files("tests/"))
        covered = self._coverage(defs, callees, test_text)

        out: list[Finding] = []
        for sf, node in surfaces:
            out.extend(self._check_surface(
                project, sf, node, defs, per_file_defs[sf.path], covered))
        return out

    # ---------------------------------------------------------- coverage
    @staticmethod
    def _coverage(defs, callees, test_text: str) -> set[str]:
        """Def names reachable from test code: mentioned directly, or
        (transitively) called by a mentioned def. Name-based, so it
        over-approximates — which is the right direction for a linter
        that wants no false 'untested' alarms."""
        covered = {name for name in defs if name in test_text}
        changed = True
        while changed:
            changed = False
            for caller in list(covered):
                for callee in callees.get(caller, ()):
                    if callee in defs and callee not in covered:
                        covered.add(callee)
                        changed = True
        return covered

    # ----------------------------------------------------------- checks
    def _check_surface(self, project: Project, sf: SourceFile,
                       node: ast.FunctionDef, defs, local_defs,
                       covered) -> list[Finding]:
        if sf.has_pragma(node, "not-parity"):
            return []
        out: list[Finding] = []
        qual = sf.qualname(node)

        declared = sf.pragma_arg(node, "parity-ref")
        if declared:
            if declared not in defs:
                out.append(Finding(
                    PASS_ID, "parity-ref-missing", sf.path, node.lineno,
                    f"{node.name} declares scalar reference {declared!r} "
                    "but no such def exists in scope", qual))
        else:
            cands = _base_candidates(node.name)
            if not any(c in local_defs for c in cands) \
                    and not any(c in defs for c in cands):
                out.append(Finding(
                    PASS_ID, "no-scalar-ref", sf.path, node.lineno,
                    f"fast path {node.name} has no scalar reference "
                    f"(looked for {', '.join(cands)}); add one, declare "
                    "it with `# lint: parity-ref(name)`, or opt out with "
                    "`# lint: not-parity(reason)`", qual))

        test_ref = sf.pragma_arg(node, "parity-test")
        if test_ref:
            if test_ref not in project.files:
                out.append(Finding(
                    PASS_ID, "parity-test-missing", sf.path, node.lineno,
                    f"{node.name} pins parity test {test_ref!r} but that "
                    "file is not in the project", qual))
        elif node.name not in covered:
            out.append(Finding(
                PASS_ID, "no-parity-test", sf.path, node.lineno,
                f"fast path {node.name} is not reachable from tests/ "
                "(neither its name nor any transitive caller's appears "
                "there); add a parity test or pin one with "
                "`# lint: parity-test(tests/test_x.py)`", qual))
        return out
