"""Pass 6 — refusal context: typed refusals carry their evidence.

``SlotExhausted`` is the executor's *typed admission refusal* — raised
before any compute is spent, and consumed programmatically by the
scheduler's retry/requeue path. Its contract is positional
``(wid, rid, limit)``; a raise-site that drops fields turns a routable
refusal into an undebuggable one. More broadly, a refusal-class
exception raised with no arguments at all ships zero context to the
log line that is usually the only artifact of a prod incident.

* ``refusal-context`` — ``raise SlotExhausted(...)`` with fewer than
  three positional/keyword arguments (or re-raising the bare class).
* ``bare-raise``      — ``raise ValueError()`` / ``RuntimeError`` /
  ``KeyError`` / ``TypeError`` with zero arguments, in ``src/repro/``.
  ``raise`` with no expression (re-raise inside ``except``) is fine.

``# lint: allow-raise(reason)`` exempts a site (e.g. an intentional
sentinel in test-support code).
"""
from __future__ import annotations

import ast

from repro.analysis.base import Finding, Project, SourceFile, dotted_name

PASS_ID = "refusals"

SCOPE = ("src/repro/",)

#: typed refusals: exception name -> minimum argument count
CONTEXT_EXCEPTIONS = {"SlotExhausted": 3}

BARE_FORBIDDEN = frozenset({
    "ValueError", "RuntimeError", "KeyError", "TypeError",
})


class RefusalsPass:
    pass_id = PASS_ID

    def run(self, project: Project) -> list[Finding]:
        out: list[Finding] = []
        for sf in project.iter_files(*SCOPE):
            out.extend(self._check_file(sf))
        return out

    def _check_file(self, sf: SourceFile) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Raise) or node.exc is None:
                continue
            if sf.has_pragma(node, "allow-raise"):
                continue
            exc = node.exc
            if isinstance(exc, ast.Call):
                name = dotted_name(exc.func).split(".")[-1]
                argc = len(exc.args) + len(exc.keywords)
            elif isinstance(exc, (ast.Name, ast.Attribute)):
                # `raise SlotExhausted` — the bare class, zero context
                name = dotted_name(exc).split(".")[-1]
                argc = 0
            else:
                continue
            need = CONTEXT_EXCEPTIONS.get(name)
            if need is not None and argc < need:
                out.append(Finding(
                    PASS_ID, "refusal-context", sf.path, node.lineno,
                    f"{name} raised with {argc} argument(s); the typed-"
                    f"refusal contract is {need} (wid, rid, limit) so the "
                    "scheduler can route the refusal", sf.scope(node)))
            elif name in BARE_FORBIDDEN and argc == 0:
                out.append(Finding(
                    PASS_ID, "bare-raise", sf.path, node.lineno,
                    f"{name} raised with no message/context; say what "
                    "value was bad and where (wid/rid/limit)",
                    sf.scope(node)))
        return out
