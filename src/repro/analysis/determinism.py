"""Pass 1 — determinism: the sim/decision path must stay bit-reproducible.

Tropical's headline claims (decision parity sim-vs-real, the CI perf
gate's attainment numbers) assume a deterministic simulation: the same
seed must produce the same decision stream on every machine, forever.
Three hazard classes are forbidden in the decision path (``sched/``,
``serving/``, ``core/``, ``workload/``) and in ``benchmarks/`` /
``examples/`` (whose published numbers must replay exactly):

* ``wallclock``     — ``time.time``/``perf_counter``/``monotonic``/
  ``process_time``, ``datetime.now``/``utcnow``. Measured-clock scopes
  (the real executor, benchmark timing harnesses) carry an explicit
  ``# lint: allow-wallclock(reason)``.
* ``unseeded-rng``  — module-level ``np.random.*`` calls (global-state
  RNG), ``default_rng()`` / ``RandomState()`` with no seed, and stdlib
  ``random.*`` module calls. Seeded generators are the only sanctioned
  source of randomness.
* ``set-iter``      — iterating a set (or feeding one to an
  order-sensitive consumer: ``list``/``tuple``/``enumerate``/``sum``/
  ``iter``) leaks hash-seed ordering into results. Order-insensitive
  consumers (``sorted``, ``len``, ``min``, ``max``, ``any``, ``all``,
  membership) are fine and not flagged.
"""
from __future__ import annotations

import ast

from repro.analysis.base import Finding, Project, SourceFile, dotted_name

PASS_ID = "determinism"

SCOPE = ("src/repro/sched/", "src/repro/serving/", "src/repro/core/",
         "src/repro/workload/", "benchmarks/", "examples/")

WALLCLOCK_CALLS = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "datetime.now", "datetime.utcnow", "datetime.datetime.now",
    "datetime.datetime.utcnow",
})

#: np.random attributes that are constructors/types, not global-state draws
RNG_SAFE_ATTRS = frozenset({
    "default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
    "MT19937", "SFC64", "RandomState", "BitGenerator",
})

#: set-consuming callables whose result does not depend on iteration order
ORDER_FREE_CONSUMERS = frozenset({
    "sorted", "len", "min", "max", "any", "all", "set", "frozenset", "bool",
})
ORDER_SENSITIVE_CONSUMERS = frozenset({
    "list", "tuple", "enumerate", "sum", "iter", "zip", "map", "filter",
})


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    return False


class DeterminismPass:
    pass_id = PASS_ID

    def run(self, project: Project) -> list[Finding]:
        out: list[Finding] = []
        for sf in project.iter_files(*SCOPE):
            out.extend(self._check_file(sf))
        return out

    def _check_file(self, sf: SourceFile) -> list[Finding]:
        out: list[Finding] = []
        # only treat bare `random.x` as the stdlib module when it was
        # actually imported as such (a local Generator named `random`
        # would otherwise false-positive)
        stdlib_random = any(
            isinstance(n, ast.Import) and any(a.name == "random"
                                              for a in n.names)
            for n in ast.walk(sf.tree))

        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                out.extend(self._check_call(sf, node, stdlib_random))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                out.extend(self._check_set_iter(sf, node.iter, node))
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    out.extend(self._check_set_iter(sf, gen.iter, node))
        return out

    # -------------------------------------------------------------- rules
    def _check_call(self, sf: SourceFile, node: ast.Call,
                    stdlib_random: bool) -> list[Finding]:
        name = dotted_name(node.func)
        out: list[Finding] = []

        if name in WALLCLOCK_CALLS:
            if not sf.has_pragma(node, "allow-wallclock"):
                out.append(Finding(
                    PASS_ID, "wallclock", sf.path, node.lineno,
                    f"wall-clock call {name}() on the deterministic path; "
                    "use the simulation clock, or annotate a measured-"
                    "clock scope with `# lint: allow-wallclock(reason)`",
                    sf.scope(node)))
            return out

        parts = name.split(".")
        # global-state numpy RNG: np.random.rand / .seed / .shuffle ...
        if len(parts) >= 3 and parts[-2] == "random" \
                and parts[0] in ("np", "numpy") \
                and parts[-1] not in RNG_SAFE_ATTRS:
            if not sf.has_pragma(node, "allow-rng"):
                out.append(Finding(
                    PASS_ID, "unseeded-rng", sf.path, node.lineno,
                    f"global-state RNG call {name}(); draw from a seeded "
                    "np.random.default_rng(seed) generator instead",
                    sf.scope(node)))
        # default_rng()/RandomState() with no seed
        elif parts and parts[-1] in ("default_rng", "RandomState") \
                and not node.args and not node.keywords:
            if not sf.has_pragma(node, "allow-rng"):
                out.append(Finding(
                    PASS_ID, "unseeded-rng", sf.path, node.lineno,
                    f"{name}() constructed without a seed: every run "
                    "draws a different stream", sf.scope(node)))
        # stdlib random module calls (random.random, random.shuffle, ...)
        elif stdlib_random and len(parts) == 2 and parts[0] == "random":
            if not sf.has_pragma(node, "allow-rng"):
                out.append(Finding(
                    PASS_ID, "unseeded-rng", sf.path, node.lineno,
                    f"stdlib global-state RNG call {name}(); use a seeded "
                    "np.random.default_rng(seed) generator",
                    sf.scope(node)))

        # order-sensitive consumption of a set expression
        if isinstance(node.func, ast.Name) \
                and node.func.id in ORDER_SENSITIVE_CONSUMERS \
                and node.args and _is_set_expr(node.args[0]):
            if not sf.has_pragma(node, "allow-set-iter"):
                out.append(Finding(
                    PASS_ID, "set-iter", sf.path, node.lineno,
                    f"{node.func.id}() over a set leaks hash ordering "
                    "into results; sort first (sorted(...)) or keep an "
                    "insertion-ordered dict", sf.scope(node)))
        return out

    def _check_set_iter(self, sf: SourceFile, iter_node: ast.AST,
                        host: ast.AST) -> list[Finding]:
        if not _is_set_expr(iter_node):
            return []
        if sf.has_pragma(host, "allow-set-iter") \
                or sf.has_pragma(iter_node, "allow-set-iter"):
            return []
        return [Finding(
            PASS_ID, "set-iter", sf.path, iter_node.lineno,
            "iterating a set: order depends on the hash seed; iterate a "
            "sorted(...) copy or an insertion-ordered dict",
            sf.scope(iter_node))]
