"""Chunked WKV6 recurrence — Pallas TPU kernel.

RWKV-6's time-mix is the attention-equivalent hot spot of the rwkv6-7b
arch: a linear recurrence with data-dependent per-channel decay,

    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T),   S_t = diag(w_t) S_{t-1} + k_t v_t^T.

The chunked-parallel form (models/rwkv6.wkv_chunked) turns the T-step scan
into T/C chunk steps of C x C / C x D matmuls — MXU work instead of a
sequential VPU scan. This kernel keeps the running (D_k x D_v) state in
VMEM f32 scratch across the chunk grid dim; all factored exponents are
taken relative to the chunk-midpoint cumulative decay, which is f32-safe
under the decay clip applied by the model (see models/rwkv6).

Grid: (B, H, T/C) — chunk dim iterates fastest. Per-step VMEM: four
(C, D) tiles + (D, D) state + (C, C) intra matrix ≈ 50 KB at C=D=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    r_ref, k_ref, v_ref, w_ref,     # (1, C, 1, D)
    u_ref,                          # (1, D)
    s0_ref,                         # (1, 1, D, D)
    o_ref,                          # (1, C, 1, D)
    sT_ref,                         # (1, 1, D, D)
    state,                          # VMEM (D, D) f32
    *,
    chunk: int,
    n_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)        # (C, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                 # (D,)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(logw, axis=0)                   # inclusive (C, D)
    total = cum[-1:, :]                              # (1, D)
    ref_row = cum[chunk // 2 - 1:chunk // 2, :]      # midpoint reference

    s = state[...]
    # state contribution: r_i ⊙ prod_{j<i} w · S   (exponent <= 0: safe)
    r_state = r * jnp.exp(cum - logw)
    out_state = jax.lax.dot_general(
        r_state, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (C, D_v)
    # intra-chunk (midpoint-referenced factorisation)
    r_dec = r * jnp.exp(cum - logw - ref_row)
    kj = k * jnp.exp(ref_row - cum)
    att = jax.lax.dot_general(
        r_dec, kj, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (C, C)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(jj < ii, att, 0.0)               # strict lower triangle
    diag = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)
    out_intra = jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + diag * v

    o_ref[0, :, 0, :] = (out_state + out_intra).astype(o_ref.dtype)

    # state update: S' = exp(total) ⊙ S + sum_j (k_j exp(total-cum_j)) v_j^T
    k_dec = k * jnp.exp(total - cum)
    state[...] = jnp.exp(total[0])[:, None] * s + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        sT_ref[0, 0] = state[...].astype(sT_ref.dtype)


def wkv6_chunked(
    r: jax.Array,       # (B, T, H, D)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,       # decay in (0,1), clipped per models/rwkv6
    u: jax.Array,       # (H, D) bonus
    s0: jax.Array,      # (B, H, D, D) f32 carried state
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    """Returns (out (B,T,H,D), sT (B,H,D,D))."""
    b, t, h, d = r.shape
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    def x_map(bi, hi, ci):
        return (bi, ci, hi, 0)

    def u_map(bi, hi, ci):
        return (hi, 0)

    def s_map(bi, hi, ci):
        return (bi, hi, 0, 0)

    grid = (b, h, n_chunks)
    kernel = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, d), x_map),
            pl.BlockSpec((1, chunk, 1, d), x_map),
            pl.BlockSpec((1, chunk, 1, d), x_map),
            pl.BlockSpec((1, chunk, 1, d), x_map),
            pl.BlockSpec((1, d), u_map),
            pl.BlockSpec((1, 1, d, d), s_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, d), x_map),
            pl.BlockSpec((1, 1, d, d), s_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )
    return kernel(r, k, v, w, u, s0)
