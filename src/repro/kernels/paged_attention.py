"""Paged decode attention — Pallas TPU kernel.

The serving hot-spot: one new query token per request attends over a
block-table-indirected paged KV cache (vLLM-style PagedAttention, adapted
to TPU: flash-decoding accumulation across sequentially-iterated grid
steps instead of CUDA split-K + shared-memory reduction).

Layout
------
  q:            (B, Hq, D)
  k_pages:      (n_pages, page_size, Hkv, D)   — global page pool
  v_pages:      (n_pages, page_size, Hkv, D)
  block_tables: (B, max_pages) int32           — per-request page ids
  lengths:      (B,) int32                     — tokens in cache (incl. new)

Grid: (B, Hkv, max_pages) — the page dim iterates fastest; the kernel
carries a running (m, l, acc) online-softmax state in VMEM scratch across
page steps and writes the output at the last page. Pages beyond a
request's length are skipped via @pl.when (their page id is clamped;
contribution masked). The page id feeds the k/v BlockSpec index_map via
scalar prefetch (pltpu.PrefetchScalarGridSpec) — the TPU-native form of
the paged indirection.

VMEM per step: one (page_size, D) K tile + V tile + (G, D) accumulator —
page_size=64, D=128 -> 64KB per tile in bf16; MXU dims (G x page_size,
page_size x D) are 128-aligned for D=128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_PAGE = 64
NEG_INF = -1e30


def _kernel(
    # scalar prefetch
    block_tables_ref,   # (B, max_pages)
    lengths_ref,        # (B,)
    # inputs
    q_ref,              # (1, 1, G, D)
    k_ref,              # (1, page_size, 1, D)
    v_ref,              # (1, page_size, 1, D)
    # outputs
    o_ref,              # (1, 1, G, D)
    # scratch
    m_ref,              # (G, 1) f32
    l_ref,              # (G, 1) f32
    acc_ref,            # (G, D) f32
    *,
    page_size: int,
    max_pages: int,
    softcap,
):
    b = pl.program_id(0)
    i = pl.program_id(2)
    length = lengths_ref[b]

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n_valid_pages = (length + page_size - 1) // page_size

    @pl.when(i < n_valid_pages)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # (ps, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        d = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * (1.0 / math.sqrt(d))                     # (G, ps)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        token_pos = i * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        s = jnp.where(token_pos < length, s, NEG_INF)

        m_prev = m_ref[...]                          # (G, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                       # (G, ps)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(i == max_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,               # (B, Hq, D)
    k_pages: jax.Array,         # (n_pages, page_size, Hkv, D)
    v_pages: jax.Array,
    block_tables: jax.Array,    # (B, max_pages) int32
    lengths: jax.Array,         # (B,) int32
    *,
    softcap: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, hq, d = q.shape
    n_pages, page_size, hkv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    g = hq // hkv
    assert g * hkv == hq, (hq, hkv)

    def q_map(bi, h, i, bt, ln):
        return (bi, h, 0, 0)

    def kv_map(bi, h, i, bt, ln):
        # clamp invalid/out-of-range pages to 0; contribution is masked
        page = bt[bi, i]
        page = jnp.where(page < 0, 0, page)
        return (page, 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), q_map),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
            pl.BlockSpec((1, page_size, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    kernel = pl.pallas_call(
        functools.partial(_kernel, page_size=page_size, max_pages=max_pages,
                          softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )
    qg = q.reshape(b, hkv, g, d)   # group-major so (b, h) tiles are (1,G,D)
    out = kernel(block_tables, lengths, qg, k_pages, v_pages)
    return out.reshape(b, hq, d)
