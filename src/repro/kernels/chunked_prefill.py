"""Chunked-prefill attention — Pallas TPU kernel.

Tropical's multiplexing workers run prefill in chunks piggybacked on decode
batches (§IV-B): a chunk of Sq new tokens, starting at per-request offset
``starts[b]``, attends to the KV cache prefix [0, starts[b]+i] (the chunk's
own K/V have already been written at [starts, starts+Sq)).

Flash-attention layout: grid (B, Hkv, Sq/bq, Sk/bk); the KV-block dim
iterates fastest and carries the online-softmax state in VMEM scratch.
KV blocks entirely above the causal frontier (or entirely below the
sliding-window floor) are skipped with @pl.when — chunked prefill against
a long prefix is mostly *skippable* work, which is where the kernel beats
a dense mask.

Block sizes default to (bq=128|Sq, bk=256) — MXU-aligned with D=64..256.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    starts_ref,           # (B,) scalar prefetch
    q_ref,                # (1, bq, 1, G, D)
    k_ref,                # (1, bk, 1, D)
    v_ref,                # (1, bk, 1, D)
    o_ref,                # (1, bq, 1, G, D)
    m_ref, l_ref, acc_ref,
    *,
    bq: int,
    bk: int,
    n_kv_blocks: int,
    softcap,
    window,
):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    start = starts_ref[b]

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal frontier for this q block: kpos <= start + iq*bq + (bq-1)
    hi = start + (iq + 1) * bq
    lo = 0 if window is None else start + iq * bq - window + 1
    block_lo = jk * bk
    relevant = (block_lo < hi) if window is None else (
        (block_lo < hi) & (block_lo + bk > lo))

    @pl.when(relevant)
    def _step():
        g, d = q_ref.shape[3], q_ref.shape[4]
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(bq * g, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * (1.0 / math.sqrt(d))                       # (bq*G, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = start + iq * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, 1, 1), 0)                  # (bq,1,1)
        kpos = block_lo + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, bk), 2)
        ok = kpos <= qpos
        if window is not None:
            ok = ok & (kpos > qpos - window)
        ok = jnp.broadcast_to(ok, (bq, g, bk)).reshape(bq * g, bk)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(jk == n_kv_blocks - 1)
    def _finalize():
        g, d = q_ref.shape[3], q_ref.shape[4]
        l = jnp.maximum(l_ref[...], 1e-30)
        out = (acc_ref[...] / l).reshape(bq, g, d)
        o_ref[0, :, 0] = out.astype(o_ref.dtype)


def chunked_prefill_attention(
    q: jax.Array,            # (B, Sq, Hq, D) — the chunk's queries (roped)
    k_cache: jax.Array,      # (B, Smax, Hkv, D) — chunk K/V already written
    v_cache: jax.Array,
    starts: jax.Array,       # (B,) int32 chunk start offsets
    *,
    softcap: float | None = None,
    window: int | None = None,
    bq: int = 128,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, sq, hq, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    bq = min(bq, sq)
    bk = min(bk, smax)
    assert sq % bq == 0 and smax % bk == 0, (sq, bq, smax, bk)
    n_kv_blocks = smax // bk

    def q_map(bi, h, iq, jk, st):
        return (bi, iq, h, 0, 0)

    def kv_map(bi, h, iq, jk, st):
        return (bi, jk, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, sq // bq, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, bq, 1, g, d), q_map),
            pl.BlockSpec((1, bk, 1, d), kv_map),
            pl.BlockSpec((1, bk, 1, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, g, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq * g, 1), jnp.float32),
            pltpu.VMEM((bq * g, 1), jnp.float32),
            pltpu.VMEM((bq * g, d), jnp.float32),
        ],
    )

    kernel = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, n_kv_blocks=n_kv_blocks,
                          softcap=softcap, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, hkv, g, d), q.dtype),
        interpret=interpret,
    )
    qg = q.reshape(b, sq, hkv, g, d)
    out = kernel(starts, qg, k_cache, v_cache)
    return out.reshape(b, sq, hq, d)
