"""jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python per grid step, validating the exact TPU program; on TPU
they compile to Mosaic. ``auto_interpret()`` picks per-backend.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.chunked_prefill import chunked_prefill_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.wkv6 import wkv6_chunked
from repro.kernels import ref


def auto_interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("softcap",))
def paged_attention_op(q, k_pages, v_pages, block_tables, lengths,
                       softcap=None):
    return paged_attention(q, k_pages, v_pages, block_tables, lengths,
                           softcap=softcap, interpret=auto_interpret())


@functools.partial(jax.jit, static_argnames=("softcap", "window", "bq", "bk"))
def chunked_prefill_op(q, k_cache, v_cache, starts, softcap=None,
                       window=None, bq=128, bk=256):
    return chunked_prefill_attention(
        q, k_cache, v_cache, starts, softcap=softcap, window=window,
        bq=bq, bk=bk, interpret=auto_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6_op(r, k, v, w, u, s0, chunk=64):
    return wkv6_chunked(r, k, v, w, u, s0, chunk=chunk,
                        interpret=auto_interpret())


paged_attention_ref = ref.paged_attention_ref
chunked_prefill_ref = ref.chunked_prefill_attention_ref
