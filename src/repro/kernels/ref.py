"""Pure-jnp oracles for the Pallas kernels (same math, no tiling)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _soft_cap(s, cap):
    return s if cap is None else cap * jnp.tanh(s / cap)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        softcap=None):
    """q: (B, Hq, D); pages (N, ps, Hkv, D); block_tables (B, P); lengths (B,).
    Gathers each request's pages into a dense (P*ps, Hkv, D) cache and runs
    masked attention."""
    b, hq, d = q.shape
    _, ps, hkv, _ = k_pages.shape
    g = hq // hkv
    max_pages = block_tables.shape[1]
    s_max = max_pages * ps

    safe_tables = jnp.maximum(block_tables, 0)
    k = k_pages[safe_tables]          # (B, P, ps, Hkv, D)
    v = v_pages[safe_tables]
    k = k.reshape(b, s_max, hkv, d)
    v = v.reshape(b, s_max, hkv, d)

    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(d)
    s = _soft_cap(s, softcap)
    mask = jnp.arange(s_max)[None, :] < lengths[:, None]   # (B, S)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def chunked_prefill_attention_ref(q, k_cache, v_cache, starts, *,
                                  softcap=None, window=None):
    """q: (B, Sq, Hq, D); caches (B, Smax, Hkv, D); starts (B,)."""
    b, sq, hq, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k_cache.astype(jnp.float32))
    s = s / math.sqrt(d)
    s = _soft_cap(s, softcap)
    qpos = starts[:, None] + jnp.arange(sq)[None, :]       # (B, Sq)
    kpos = jnp.arange(smax)
    ok = kpos[None, None, :] <= qpos[:, :, None]           # (B, Sq, Smax)
    if window is not None:
        ok = ok & (kpos[None, None, :] > qpos[:, :, None] - window)
    s = jnp.where(ok[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)
