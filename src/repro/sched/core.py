"""ClusterScheduler — the one scheduling code path behind every executor.

Owns dispatch, the global overflow queue, per-worker iteration planning,
decode routing/KV migration, failure/recovery/elastic-add lifecycle, the
§IV-C predictor feedback loop and event-driven role rebalancing. It is
clock-free: a *driver* (the discrete-event ``Simulator``, or any real-time
loop) owns time, feeds events in via ``handle(kind, now, payload)`` and
lends the scheduler a ``defer(kind, time, payload)`` callback for the
events the scheduler itself originates (iteration completions, migration
arrivals, transfer ticks, rebalance reviews). Compute lives behind the
``ExecutionBackend`` protocol.

Event kinds (payloads):
  arrival         Request
  iter_done       (wid, IterationPlan, duration)
  migration_done  (dst_wid, Request, started_at, src_wid)
  transfer_tick   transfer-engine version stamp
  offload_done    (wid, Request)        KV landed in the host-DRAM tier
  restore_done    (wid, Request)        KV pulled back into HBM
  fail            (wid, recover_after | None)
  recover         wid
  add_worker      Worker
  rebalance       None
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

from repro.core.metrics import ServeMetrics, compute_metrics
from repro.core.policies import Policy
from repro.core.request import Phase, Request
from repro.sched.backend import CostModelBackend, ExecutionBackend, \
    SlotExhausted
from repro.sched.rebalance import RoleRebalancer
from repro.serving.engine import IterationPlan, Worker, _slack_key
from repro.serving.transfer import LinkSpec, host_node


class ClusterScheduler:
    def __init__(self, workers: Sequence[Worker], policy: Policy,
                 backend: Optional[ExecutionBackend] = None,
                 transfer=None,
                 rebalancer: Optional[RoleRebalancer] = None,
                 drift_monitor=None,
                 record_decisions: bool = False):
        self.workers: dict[int, Worker] = {w.wid: w for w in workers}
        self.policy = policy
        self.backend = backend or CostModelBackend()
        # optional online recalibration (repro.perf.recalibrate): observed
        # iteration residuals re-fit per-bucket γ + efficiency constants
        self.drift_monitor = drift_monitor
        self.transfer = transfer
        if transfer is not None:
            for w in workers:
                transfer.add_worker(
                    w.wid, LinkSpec.from_hardware(w.cost.worker.hw))
                if w.pages.host_total_pages > 0:
                    transfer.add_host(
                        w.wid, LinkSpec.from_host_hardware(w.cost.worker.hw))
        self.rebalancer = rebalancer
        # overflow queue as an insertion-ordered dict {rid: req}: O(1)
        # membership/removal where the old list paid O(n) scans per event,
        # while iteration keeps exact arrival order (drain parity)
        self.global_queue: dict[int, Request] = {}
        # live class-name counts for the queued set, maintained
        # incrementally so the multi-tenant drain check below is O(1)
        # instead of a full rescan per drain
        self._gq_classes: dict[str, int] = {}
        self.requests: list[Request] = []
        self._handlers: dict[str, Callable] = {}
        self._busy: dict[int, bool] = {w.wid: False for w in workers}
        # decision log: dispatch targets, batch compositions, decode routes.
        # The backend-parity test replays one trace through two backends and
        # asserts these are identical — the guarantee that simulator and
        # real executor share one scheduling brain.
        self.decisions: Optional[list[tuple]] = [] if record_decisions else None
        self._defer: Optional[Callable[[str, float, object], None]] = None
        self._rebalance_armed = False

    # ------------------------------------------------------------- driver api
    def bind(self, defer: Callable[[str, float, object], None]) -> None:
        """Give the scheduler its driver's event sink."""
        self._defer = defer

    def handle(self, kind: str, now: float, payload=None) -> None:
        h = self._handlers.get(kind)
        if h is None:
            h = self._handlers[kind] = getattr(self, f"_on_{kind}")
        h(now, payload)

    def handle_batch(self, now: float, events) -> None:
        """Process a same-timestamp run of ``(time, seq, kind, payload)``
        heap tuples in order. Semantically identical to calling ``handle``
        per event — coalescing exists so per-timestamp overhead (handler
        lookup per same-kind run, the driver's pop/dispatch round-trips)
        is paid once per batch; view-column syncs stay lazy/dirty-row so
        they already collapse across the batch."""
        handlers = self._handlers
        i, m = 0, len(events)
        while i < m:
            kind = events[i][2]
            h = handlers.get(kind)
            if h is None:
                h = handlers[kind] = getattr(self, f"_on_{kind}")
            h(now, events[i][3])
            j = i + 1
            while j < m and events[j][2] == kind:
                h(now, events[j][3])
                j += 1
            i = j

    def metrics(self) -> ServeMetrics:
        qt, bt = {}, {}
        counters = {"prefix_lookups": 0, "prefix_hits": 0,
                    "kv_offloads": 0, "kv_restores": 0,
                    "pages_offloaded": 0, "pages_restored": 0,
                    "pages_reprefilled": 0}
        for w in self.workers.values():
            qt.update(w.queue_times)
            bt.update(w.blocked_time)
            counters["kv_offloads"] += w.offload_count
            counters["kv_restores"] += w.restore_count
            counters["pages_offloaded"] += w.pages_offloaded
            counters["pages_restored"] += w.pages_restored
            counters["pages_reprefilled"] += w.pages_reprefilled
            if w.prefix_cache is not None:
                counters["prefix_lookups"] += w.prefix_cache.lookups
                counters["prefix_hits"] += w.prefix_cache.hits
        return compute_metrics(self.requests, qt, bt, counters=counters)

    # --------------------------------------------------------------- events
    def _on_arrival(self, now: float, req: Request) -> None:
        self.requests.append(req)
        self._try_dispatch(req, now)
        self._arm_rebalance(now)

    def _try_dispatch(self, req: Request, now: float) -> None:
        wid = self.policy.dispatch_prefill(req, now)
        ok = wid is not None and wid in self.workers \
            and self.workers[wid].view.alive
        if self.decisions is not None:
            self.decisions.append(("dispatch", req.rid, wid if ok else None))
        if not ok:
            if req.rid not in self.global_queue:
                self.global_queue[req.rid] = req
                name = req.slo.name
                self._gq_classes[name] = self._gq_classes.get(name, 0) + 1
            return
        if self.global_queue.pop(req.rid, None) is not None:
            name = req.slo.name
            left = self._gq_classes[name] - 1
            if left:
                self._gq_classes[name] = left
            else:
                del self._gq_classes[name]
        self.workers[wid].admit_prefill(req, now)
        self._kick(wid, now)

    def _drain_global_queue(self, now: float) -> None:
        queue = list(self.global_queue.values())
        if len(self._gq_classes) > 1:
            # multi-tenant overflow: offer dispatch slots tightest-relative-
            # TTFT-slack first across classes (absolute seconds don't
            # compare across SLO tiers), hopeless requests last; a single-
            # class queue keeps its arrival order, preserving pre-SLO-class
            # decision parity
            queue.sort(key=_slack_key(now))
        for req in queue:
            self._try_dispatch(req, now)

    def _kick(self, wid: int, now: float) -> None:
        """Start an iteration on a now-idle worker if it has work."""
        w = self.workers[wid]
        if self._busy[wid] or not w.view.alive:
            return
        head = w.peek_prefill(now)
        rule = self.policy.batch_rule(w.view, now, head)
        plan = w.compose_iteration(rule, now)
        if plan.empty:
            return
        if self.decisions is not None:
            self.decisions.append((
                "iter", wid,
                tuple(r.rid for r in plan.decode_reqs),
                tuple((r.rid, t) for r, t in plan.prefill_parts)))
        try:
            dur = self.backend.run_iteration(w, plan)
        except SlotExhausted as exc:
            # the backend refused the plan's NEW prefill (per-worker slot
            # capacity, a real-hardware constraint the view's HBM watermark
            # does not model) before running any compute: requeue that
            # request globally and re-kick the worker with the rest
            self._refuse_prefill(w, plan, exc.rid, now)
            return
        self._busy[wid] = True
        self._defer("iter_done", now + dur, (wid, plan, dur))

    def _refuse_prefill(self, w: Worker, plan: IterationPlan, rid: int,
                        now: float) -> None:
        """Back out one refused first-chunk prefill: undo its admission on
        the worker, return it to the global overflow queue (NOT
        ``_try_dispatch`` — the policy would place it straight back on the
        same slot-full worker), and let the worker run its remaining
        work."""
        req = next(r for r, _ in plan.prefill_parts if r.rid == rid)
        if self.decisions is not None:
            self.decisions.append(("refuse", w.wid, rid))
        w.withdraw_prefill(req)           # queue + pages + prefix ref + kv
        req.reset_for_reprefill(now)
        if req.rid not in self.global_queue:
            self.global_queue[req.rid] = req
            name = req.slo.name
            self._gq_classes[name] = self._gq_classes.get(name, 0) + 1
        self._kick(w.wid, now)

    def _on_iter_done(self, now: float, payload) -> None:
        wid, plan, dur = payload
        w = self.workers[wid]
        self._busy[wid] = False
        if not w.view.alive:
            return
        self._observe(wid, plan, dur)
        finished_prefills = w.complete_iteration(plan, now, dur)
        self._record_outcomes(plan, finished_prefills)
        for req in finished_prefills:
            self._route_decode(w, req, now)
        # watermark evictions re-enter global dispatch (re-prefill cost)
        for req in w.drain_preempted():
            self.backend.on_finish(req)      # execution state restarts too
            self._try_dispatch(req, now)
        # watermark offloads spill to the host tier over the DMA link;
        # freed HBM may in turn let a parked request come back
        for req in w.drain_offload_started():
            self._start_offload(w, req, now)
        self._maybe_restore(w, now)
        self._drain_global_queue(now)
        self._kick(wid, now)
        self._arm_rebalance(now)

    def _route_decode(self, src: Worker, req: Request, now: float) -> None:
        target = self.policy.dispatch_decode(req, now)
        if self.decisions is not None:
            self.decisions.append(("route", req.rid, src.wid, target))
        if target is None or target == src.wid:
            src.admit_decode(req, now)
            self._kick(src.wid, now)
            return
        # KV migration: src frees; target admits when the bytes have crossed
        # the (possibly contended) ICI links
        req.migrations += 1
        req.phase = Phase.MIGRATING
        src.release(req)
        if self.transfer is None:
            delay = src.cost.migration_time(req.context_len)
            self._defer("migration_done", now + delay,
                        (target, req, now, src.wid))
            return
        nbytes = src.cost.kv_transfer_bytes(req.context_len)
        self.transfer.start(src.wid, target, nbytes, now,
                            payload=(target, req, now, src.wid))
        self._schedule_transfer_tick(now)

    # ------------------------------------------------- tiered KV (host DRAM)
    def _start_offload(self, w: Worker, req: Request, now: float) -> None:
        """Push a watermark victim's KV pages over the host DMA link. The
        pages were already moved to the host tier in the accountant (HBM is
        freed immediately — that is the point of the spill); the flow models
        the wire time before the copy is *restorable*."""
        if self.decisions is not None:
            self.decisions.append(("offload", req.rid, w.wid))
        if self.transfer is None:
            delay = w.cost.restore_time(req.context_len)
            self._defer("offload_done", now + delay, (w.wid, req))
            return
        nbytes = w.cost.kv_transfer_bytes(req.context_len)
        self.transfer.start(w.wid, host_node(w.wid), nbytes, now,
                            payload=("offload", w.wid, req))
        self._schedule_transfer_tick(now)

    def _on_offload_done(self, now: float, payload) -> None:
        wid, req = payload
        w = self.workers.get(wid)
        if w is None or not w.view.alive:
            return          # fail() already restarted the request
        if w.offloading.get(req.rid) is not req:
            return          # stale (worker failed and recovered meanwhile)
        w.offload_landed(req)
        self._maybe_restore(w, now)

    def _maybe_restore(self, w: Worker, now: float) -> None:
        """Pull parked requests back into HBM while they fit below the
        watermark (FIFO over the parked set — oldest spill returns first)."""
        if not w.view.alive:
            return
        while True:
            req = w.next_restorable()
            if req is None or not w.begin_restore(req, now):
                return
            if self.decisions is not None:
                self.decisions.append(("restore", req.rid, w.wid))
            if self.transfer is None:
                delay = w.cost.restore_time(req.context_len)
                self._defer("restore_done", now + delay, (w.wid, req))
                continue
            nbytes = w.cost.kv_transfer_bytes(req.context_len)
            self.transfer.start(host_node(w.wid), w.wid, nbytes, now,
                                payload=("restore", w.wid, req))
            self._schedule_transfer_tick(now)

    def _on_restore_done(self, now: float, payload) -> None:
        wid, req = payload
        w = self.workers.get(wid)
        if w is None or not w.view.alive:
            return          # fail() already restarted the request
        if w.finish_restore(req, now):
            self._kick(wid, now)

    # -------------------------------------------------- contended transfers
    def _schedule_transfer_tick(self, now: float) -> None:
        t = self.transfer.next_completion()
        if t is not None:
            self._defer("transfer_tick", max(t, now), self.transfer.version)

    @staticmethod
    def _flow_event(flow) -> tuple[str, object]:
        """Map a completed flow to its event. Host-tier flows carry
        string-tagged payloads ("offload"|"restore", wid, req); migration
        flows keep the legacy 4-tuple (target, req, started, src_wid)."""
        p = flow.payload
        if isinstance(p, tuple) and p and p[0] in ("offload", "restore"):
            return f"{p[0]}_done", p[1:]
        return "migration_done", p

    def _on_transfer_tick(self, now: float, version) -> None:
        if version != self.transfer.version:
            return                           # rates changed since scheduling
        for flow in self.transfer.pop_completed(now):
            latency = self.transfer.delivery_latency(flow.src)
            kind, payload = self._flow_event(flow)
            self._defer(kind, now + latency, payload)
        self._schedule_transfer_tick(now)

    def _on_migration_done(self, now: float, payload) -> None:
        wid, req, started, src_wid = payload
        wait = now - started
        req.migration_wait += wait
        if req.generated_tokens > 0:
            # the user is mid-stream: time on the wire is inter-token
            # latency — it burns TPOT budget exactly like a stalled
            # iteration (the D->P/P->D asymmetry cost the paper's toggle
            # avoids by keeping decodes in place)
            req.decode_time += wait
            req.tpot_slack -= wait
        w = self.workers.get(wid)
        if w is None or not w.view.alive or \
                not w.admit_migrated(req, now):
            self.backend.on_finish(req)
            req.restarts += 1
            req.reset_for_reprefill(now)
            self._try_dispatch(req, now)
            return
        try:
            self.backend.on_migrate(req, src_wid, wid)
        except SlotExhausted:
            # destination has HBM room but no free KV slot: undo the admit
            # and fall back to the failed-placement restart path
            w.release(req)
            self.backend.on_finish(req)
            req.restarts += 1
            req.reset_for_reprefill(now)
            self._try_dispatch(req, now)
            return
        self._kick(wid, now)
        self._arm_rebalance(now)

    # ------------------------------------------------------ fault tolerance
    def _on_fail(self, now: float, payload) -> None:
        wid, recover_after = payload
        w = self.workers.get(wid)
        if w is None:
            return
        lost = w.fail(now)
        self.policy.on_worker_failure(wid)
        if self.transfer is not None:
            # KV in flight to OR from the dead worker is lost: restart.
            # Host-tier flows (tagged payloads) touch the worker's own host
            # node; their requests were already restarted by w.fail().
            dropped = self.transfer.drop_flows_touching(wid, now)
            dropped += self.transfer.drop_flows_touching(host_node(wid), now)
            for flow in dropped:
                if self._flow_event(flow)[0] != "migration_done":
                    continue
                _, req, started, _src = flow.payload
                req.migration_wait += now - started
                req.restarts += 1
                req.reset_for_reprefill(now)
                lost.append(req)
            self._schedule_transfer_tick(now)
        for r in lost:
            if r.phase != Phase.FINISHED:
                self.backend.on_finish(r)
                self._try_dispatch(r, now)
        if recover_after is not None:
            self._defer("recover", now + recover_after, wid)

    def _on_recover(self, now: float, wid: int) -> None:
        w = self.workers.get(wid)
        if w is None:
            return
        w.view.alive = True
        self._drain_global_queue(now)
        self._kick(wid, now)
        self._arm_rebalance(now)

    def _on_add_worker(self, now: float, w: Worker) -> None:
        self.workers[w.wid] = w
        self._busy[w.wid] = False
        if self.drift_monitor is not None:
            self.drift_monitor.register(w.wid, w.cost)
        if self.transfer is not None:
            self.transfer.add_worker(
                w.wid, LinkSpec.from_hardware(w.cost.worker.hw))
            if w.pages.host_total_pages > 0:
                self.transfer.add_host(
                    w.wid, LinkSpec.from_host_hardware(w.cost.worker.hw))
        self.policy.workers[w.wid] = w.view
        if getattr(self.policy, "toggle", None) is not None:
            self.policy.toggle.workers[w.wid] = w.view
        self._drain_global_queue(now)
        self._arm_rebalance(now)

    # --------------------------------------------------- feedback + roles
    def _observe(self, wid: int, plan: IterationPlan, dur: float) -> None:
        """Close the §IV-C loop: feed the observed iteration duration back
        to the predictor (OnlinePredictor EWMA-corrects; others ignore),
        tagged with the worker that ran it so per-worker calibration
        (heterogeneous clusters) converges independently per worker."""
        observe = getattr(self.policy.predictor, "observe_iteration", None)
        if observe is not None:
            observe(plan.n_decode, plan.sum_ctx, plan.prefill_tokens,
                    plan.prefill_ctx_offset, dur, wid=wid)
        if self.drift_monitor is not None:
            w = self.workers.get(wid)
            if w is not None:
                # residual vs the worker model's *current* prediction: the
                # DriftMonitor re-fits γ / efficiency from what's left
                self.drift_monitor.observe(wid, plan, w.plan_duration(plan),
                                           dur)

    def _record_outcomes(self, plan: IterationPlan,
                         finished_prefills: list[Request]) -> None:
        finished = [r for r in plan.decode_reqs if r.phase == Phase.FINISHED]
        finished += [r for r, _ in plan.prefill_parts
                     if r.phase == Phase.FINISHED]
        for r in finished:
            self.backend.on_finish(r)
        if self.rebalancer is None:
            return
        for r in finished_prefills:
            self.rebalancer.record_first_token(r)
        for r, _ in plan.prefill_parts:
            if r.phase == Phase.FINISHED and r not in finished_prefills:
                self.rebalancer.record_first_token(r)   # 0-decode requests
        for r in finished:
            self.rebalancer.record_finish(r)

    def _arm_rebalance(self, now: float) -> None:
        if self.rebalancer is None or self._rebalance_armed:
            return
        self._rebalance_armed = True
        self._defer("rebalance", now + self.rebalancer.cfg.interval, None)

    def _on_rebalance(self, now: float, _payload) -> None:
        self._rebalance_armed = False
        action = self.rebalancer.step(
            {wid: w.view for wid, w in self.workers.items()}, now)
        if action is not None:
            # roles changed: queued work may have new admissible homes
            self._drain_global_queue(now)
            for wid in list(self.workers):
                self._kick(wid, now)
        if self._progress_pending():
            self._arm_rebalance(now)

    def _progress_pending(self) -> bool:
        """True when some non-rebalance event is still coming (an iteration
        or a transfer in flight). Queued-but-stuck work alone must NOT keep
        the review timer alive: with nothing else in flight no review can
        make progress, and perpetual self-re-arming would keep the driver's
        heap non-empty forever (an unbounded ``run()`` would never return).
        Any later arrival/completion/recovery re-arms the timer."""
        if any(self._busy.values()):
            return True
        return (self.transfer is not None
                and self.transfer.next_completion() is not None)
