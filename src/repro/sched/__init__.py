"""Unified scheduling core.

One SLO-aware ``ClusterScheduler`` owns dispatch, the global queue,
iteration planning, decode routing and role lifecycle; clock/compute
sources (the discrete-event ``Simulator``, the real-JAX executor, the
trace-replay stream) drive it through the narrow ``ExecutionBackend``
protocol, so every execution substrate exercises the *same* scheduling
code path.
"""
from repro.sched.backend import (CallableBackend, CostModelBackend,
                                 ExecutionBackend, TraceReplayBackend)
from repro.sched.core import ClusterScheduler
from repro.sched.rebalance import RebalanceConfig, RoleRebalancer

__all__ = [
    "CallableBackend",
    "ClusterScheduler",
    "CostModelBackend",
    "ExecutionBackend",
    "RebalanceConfig",
    "RoleRebalancer",
    "TraceReplayBackend",
]
