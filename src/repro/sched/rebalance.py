"""Event-driven elastic role rebalancing.

The toggle's §IV-C role review originally ran as a side effect of
``dispatch_prefill`` (every N-th dispatch), which couples role lifecycle to
arrival timing: a quiet cluster never reviews, a bursty one reviews at the
worst moments, and the signal (a dispatch-failure counter) says nothing
about what users actually experienced. The ``RoleRebalancer`` instead runs
on scheduler-clock events and decides from *windowed SLO attainment*: the
scheduler records every first-token (TTFT) and every finish (TPOT) outcome,
and at each review the rebalancer promotes/demotes PREFILL / MULTIPLEX
workers toward whichever phase is missing its SLO — falling back to the
paper's HBM-watermark rule, which stays load-bearing under memory pressure.

Multi-tenant: outcomes are windowed **per SLO class** and reviews act on
the *worst* class's attainment, so a healthy aggregate can no longer mask
a starving tight-SLO tenant behind an over-served batch tenant (the
failure mode "Taming Request Imbalance" (arXiv:2605.02329) schedules
against). Single-class traffic reduces to the old aggregate window.

At 100+-worker scale, one role move per review is too slow to chase a
breach. ``confirm_windows``/``max_move_frac`` add proportional moves with
hysteresis: after ``confirm_windows`` *consecutive* breach reviews (a lone
bad window never triggers a reconfiguration), move
``ceil(deficit_fraction x convertible workers)`` at once, capped at
``ceil(max_move_frac x alive workers)`` per review. Defaults reproduce the
legacy controller exactly (act on the first breach, one worker per
review).
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Optional

from repro.core.request import Request
from repro.core.toggle import Role, WorkerView


@dataclasses.dataclass(frozen=True)
class RebalanceConfig:
    interval: float = 5.0         # seconds between reviews
    window: int = 64              # outcomes per class per sliding window
    min_samples: int = 12         # don't act on thinner per-class evidence
    ttft_target: float = 0.9      # windowed attainment floors
    tpot_target: float = 0.9
    cooldown: float = 10.0        # seconds between role changes
    demote_hbm_max: float = 0.5   # only turn an M into a P below this util
    hbm_watermark: float = 0.90   # paper rule: all M above -> P becomes M
    confirm_windows: int = 1      # consecutive breach reviews before acting
                                  # (hysteresis; 1 = legacy immediate)
    max_move_frac: float = 0.0    # >0: proportional moves, ceil(deficit x
                                  # convertible) capped at ceil(frac x
                                  # alive) per review; 0 = legacy single
                                  # move
    window_ttl: Optional[float] = None
                                  # seconds a class's window stays live
                                  # after its last outcome: a tenant that
                                  # stops sending traffic expires instead
                                  # of pinning worst-class reviews to a
                                  # stale window. None = never expire (the
                                  # legacy behaviour; continuously-active
                                  # classes are unaffected either way)


class RoleRebalancer:
    """Windowed-attainment role controller. The scheduler feeds it outcome
    events; ``step`` applies at most one review's worth of role changes."""

    def __init__(self, config: RebalanceConfig = RebalanceConfig()):
        self.cfg = config
        # per-SLO-class sliding windows; legacy aggregate callers land in
        # the eagerly-created "default" class deques (kept as attributes)
        self.ttft_windows: dict[str, deque] = {}
        self.tpot_windows: dict[str, deque] = {}
        self.ttft_window = self._window(self.ttft_windows, "default")
        self.tpot_window = self._window(self.tpot_windows, "default")
        self._last_change = float("-inf")
        self._ttft_streak = 0         # consecutive breach reviews
        self._tpot_streak = 0
        self._last_outcome: dict[str, float] = {}   # class -> latest event
        self.transitions: list[tuple[float, int, Role]] = []   # audit trail

    def _window(self, windows: dict[str, deque], name: str) -> deque:
        if name not in windows:
            windows[name] = deque(maxlen=self.cfg.window)
        return windows[name]

    # ------------------------------------------------------------- signals
    def record_first_token(self, req: Request) -> None:
        self._window(self.ttft_windows, req.slo.name).append(req.ttft_ok())
        if req.first_token_time is not None:
            self._touch(req.slo.name, req.first_token_time)

    def record_finish(self, req: Request) -> None:
        self._window(self.tpot_windows, req.slo.name).append(req.tpot_ok())
        if req.finish_time is not None:
            self._touch(req.slo.name, req.finish_time)

    def _touch(self, name: str, t: float) -> None:
        self._last_outcome[name] = max(self._last_outcome.get(name, t), t)

    def _expire_stale_windows(self, now: float) -> None:
        """Time-based decay: a class silent for longer than ``window_ttl``
        stops contributing evidence — its window describes traffic that no
        longer exists, and worst-class reviews must not chase it. Directly
        populated windows with no recorded outcome timestamp (legacy
        aggregate callers) never expire."""
        ttl = self.cfg.window_ttl
        if ttl is None:
            return
        for name, last in list(self._last_outcome.items()):
            if now - last > ttl:
                for windows in (self.ttft_windows, self.tpot_windows):
                    if name in windows:
                        windows[name].clear()
                del self._last_outcome[name]

    def _worst_attainment(self, windows: dict[str, deque]) -> Optional[float]:
        """Attainment of the worst class with enough evidence (None when no
        class clears ``min_samples``). With one populated class this *is*
        the aggregate window — the pre-multi-tenant behaviour."""
        atts = [sum(w) / len(w) for w in windows.values()
                if len(w) >= self.cfg.min_samples]
        return min(atts) if atts else None

    # -------------------------------------------------------------- review
    def _n_moves(self, deficit: float, convertible: int, alive: int) -> int:
        """Workers to move this review: proportional to how far the worst
        class is below target, bounded by the per-review cap."""
        if self.cfg.max_move_frac <= 0.0:
            return 1
        want = math.ceil(deficit * convertible)
        cap = math.ceil(self.cfg.max_move_frac * alive)
        return max(1, min(want, cap, convertible))

    def step(self, workers: dict[int, WorkerView], now: float) -> Optional[str]:
        """Review roles; mutate ``WorkerView.role`` on up to one review's
        move budget. Returns a human-readable action description, or
        None."""
        cfg = self.cfg
        self._expire_stale_windows(now)
        alive = [w for w in workers.values() if w.alive]
        m = [w for w in alive if w.role == Role.MULTIPLEX]
        p = [w for w in alive if w.role == Role.PREFILL]

        # paper §IV-C memory-pressure rule first: every multiplexing worker
        # above the HBM watermark starves decode admission cluster-wide.
        # Queued work is priced on the candidate's own hardware (tokens /
        # relative speed): the cheapest P to flip is the one whose backlog
        # clears soonest, not the one with the fewest raw tokens.
        if m and p and all(w.hbm_util > cfg.hbm_watermark for w in m):
            conv = min(p, key=lambda w: w.queued_prefill_tokens / w.speed)
            return self._apply([conv], Role.MULTIPLEX, now, "hbm-pressure")

        ttft_att = self._worst_attainment(self.ttft_windows)
        tpot_att = self._worst_attainment(self.tpot_windows)
        ttft_bad = ttft_att is not None and ttft_att < cfg.ttft_target
        tpot_bad = tpot_att is not None and tpot_att < cfg.tpot_target
        # hysteresis streaks advance on every review, including those that
        # land inside the cooldown — the cooldown delays acting, it must
        # not erase the evidence that a breach persisted through it
        self._ttft_streak = self._ttft_streak + 1 if ttft_bad else 0
        self._tpot_streak = self._tpot_streak + 1 if tpot_bad else 0

        if now - self._last_change < cfg.cooldown:
            return None

        if ttft_bad and not tpot_bad \
                and self._ttft_streak >= cfg.confirm_windows and len(m) > 1:
            # prefill capacity starved while decode is healthy: flip the
            # least decode-committed multiplexers (cheap direction —
            # running decodes drain in place, no migration)
            cands = [w for w in m if w.hbm_util < cfg.demote_hbm_max]
            if cands:
                deficit = (cfg.ttft_target - ttft_att) / cfg.ttft_target
                n = min(self._n_moves(deficit, len(cands), len(alive)),
                        len(m) - 1)         # never demote the last M
                cands.sort(key=lambda w: (w.decode_batch,
                                          w.decode_sum_ctx / w.speed))
                return self._apply(cands[:n], Role.PREFILL, now,
                                   "ttft-window")
        if tpot_bad and not ttft_bad \
                and self._tpot_streak >= cfg.confirm_windows and p:
            # decode capacity starved: the least-queued prefill workers
            # start multiplexing (admission-only change)
            deficit = (cfg.tpot_target - tpot_att) / cfg.tpot_target
            n = self._n_moves(deficit, len(p), len(alive))
            p.sort(key=lambda w: w.queued_prefill_tokens / w.speed)
            return self._apply(p[:n], Role.MULTIPLEX, now, "tpot-window")
        return None

    def _apply(self, ws: list[WorkerView], role: Role, now: float,
               reason: str) -> str:
        for w in ws:
            w.role = role
            self.transitions.append((now, w.wid, role))
        self._last_change = now
        self._ttft_streak = 0
        self._tpot_streak = 0
        wids = ", ".join(str(w.wid) for w in ws)
        return f"{reason}: worker {wids} -> {role.value}"
