"""Event-driven elastic role rebalancing.

The toggle's §IV-C role review originally ran as a side effect of
``dispatch_prefill`` (every N-th dispatch), which couples role lifecycle to
arrival timing: a quiet cluster never reviews, a bursty one reviews at the
worst moments, and the signal (a dispatch-failure counter) says nothing
about what users actually experienced. The ``RoleRebalancer`` instead runs
on scheduler-clock events and decides from *windowed SLO attainment*: the
scheduler records every first-token (TTFT) and every finish (TPOT) outcome,
and at each review the rebalancer promotes/demotes PREFILL / MULTIPLEX
workers toward whichever phase is missing its SLO — falling back to the
paper's HBM-watermark rule, which stays load-bearing under memory pressure.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

from repro.core.request import Request
from repro.core.toggle import Role, WorkerView


@dataclasses.dataclass(frozen=True)
class RebalanceConfig:
    interval: float = 5.0         # seconds between reviews
    window: int = 64              # outcomes per sliding window
    min_samples: int = 12         # don't act on thinner evidence
    ttft_target: float = 0.9      # windowed attainment floors
    tpot_target: float = 0.9
    cooldown: float = 10.0        # seconds between role changes
    demote_hbm_max: float = 0.5   # only turn an M into a P below this util
    hbm_watermark: float = 0.90   # paper rule: all M above -> P becomes M


class RoleRebalancer:
    """Windowed-attainment role controller. The scheduler feeds it outcome
    events; ``step`` applies at most one role change per review."""

    def __init__(self, config: RebalanceConfig = RebalanceConfig()):
        self.cfg = config
        self.ttft_window: deque[bool] = deque(maxlen=config.window)
        self.tpot_window: deque[bool] = deque(maxlen=config.window)
        self._last_change = float("-inf")
        self.transitions: list[tuple[float, int, Role]] = []   # audit trail

    # ------------------------------------------------------------- signals
    def record_first_token(self, req: Request) -> None:
        self.ttft_window.append(req.ttft_ok())

    def record_finish(self, req: Request) -> None:
        self.tpot_window.append(req.tpot_ok())

    @staticmethod
    def _attainment(window: deque) -> Optional[float]:
        return sum(window) / len(window) if window else None

    # -------------------------------------------------------------- review
    def step(self, workers: dict[int, WorkerView], now: float) -> Optional[str]:
        """Review roles; mutate at most one ``WorkerView.role``. Returns a
        human-readable action description, or None."""
        cfg = self.cfg
        alive = [w for w in workers.values() if w.alive]
        m = [w for w in alive if w.role == Role.MULTIPLEX]
        p = [w for w in alive if w.role == Role.PREFILL]

        # paper §IV-C memory-pressure rule first: every multiplexing worker
        # above the HBM watermark starves decode admission cluster-wide
        if m and p and all(w.hbm_util > cfg.hbm_watermark for w in m):
            conv = min(p, key=lambda w: w.queued_prefill_tokens)
            return self._apply(conv, Role.MULTIPLEX, now, "hbm-pressure")

        if now - self._last_change < cfg.cooldown:
            return None

        ttft_att = self._attainment(self.ttft_window)
        tpot_att = self._attainment(self.tpot_window)
        ttft_bad = (len(self.ttft_window) >= cfg.min_samples
                    and ttft_att < cfg.ttft_target)
        tpot_bad = (len(self.tpot_window) >= cfg.min_samples
                    and tpot_att < cfg.tpot_target)

        if ttft_bad and not tpot_bad and len(m) > 1:
            # prefill capacity starved while decode is healthy: flip the
            # least decode-committed multiplexer (cheap direction — running
            # decodes drain in place, no migration)
            cands = [w for w in m if w.hbm_util < cfg.demote_hbm_max]
            if cands:
                conv = min(cands, key=lambda w: (w.decode_batch,
                                                 w.decode_sum_ctx))
                return self._apply(conv, Role.PREFILL, now, "ttft-window")
        if tpot_bad and not ttft_bad and p:
            # decode capacity starved: the least-queued prefill worker
            # starts multiplexing (admission-only change)
            conv = min(p, key=lambda w: w.queued_prefill_tokens)
            return self._apply(conv, Role.MULTIPLEX, now, "tpot-window")
        return None

    def _apply(self, w: WorkerView, role: Role, now: float,
               reason: str) -> str:
        w.role = role
        self._last_change = now
        self.transitions.append((now, w.wid, role))
        return f"{reason}: worker {w.wid} -> {role.value}"
