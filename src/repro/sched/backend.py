"""ExecutionBackend — the narrow seam between scheduling and execution.

The ``ClusterScheduler`` never runs compute and never reads a wall clock;
it asks its backend to execute one composed iteration and report how long
it took (simulated or measured), and notifies it of the few lifecycle
events an execution substrate must mirror (request teardown, KV
migration). Everything else — dispatch, queueing, routing, role changes —
is backend-agnostic scheduler code.

Implementations:

* ``CostModelBackend`` — the analytical roofline clock (discrete-event
  simulation; default).
* ``CallableBackend`` — adapts a bare ``duration_fn(worker, plan)`` (the
  legacy ``Simulator.duration_fn`` hook, noise-injection experiments).
* ``TraceReplayBackend`` — streams a recorded/synthesised trace
  (``Scenario.replay`` / ``replay_csv`` iterators) into the driver lazily
  while an inner backend supplies durations: arrivals need never be
  materialised up front, which is how a recorded production trace with
  millions of requests replays in O(1) pending-arrival memory.
* ``RealJaxBackend`` (serving/executor.py) — actually runs the JAX model
  and measures wall-clock, or runs it under the cost-model clock for
  decision-parity tests against the simulator.
* ``CalibratedRooflineBackend`` (repro.perf.calibrate) — the analytic
  clock re-instantiated from measured Pallas-kernel MFU/bandwidth.
"""
from __future__ import annotations

from typing import Callable, Iterable, Iterator, Optional, Protocol, \
    runtime_checkable

from repro.core.request import Request
from repro.serving.engine import IterationPlan, Worker


class SlotExhausted(RuntimeError):
    """A backend ran out of per-worker KV slots for a new request.

    Raised by ``RealExecutor._slot`` (and any backend with bounded
    per-worker request state) BEFORE any compute runs, so the scheduler
    can treat it as a dispatch refusal — requeue the request globally and
    retry once a slot frees — rather than a crash. Carries the worker,
    the refused request, and the capacity so the refusal is loggable."""

    def __init__(self, wid: int, rid: int, max_slots: int):
        super().__init__(
            f"worker {wid}: no free KV slot for request {rid} "
            f"(max_slots={max_slots})")
        self.wid = wid
        self.rid = rid
        self.max_slots = max_slots


@runtime_checkable
class ExecutionBackend(Protocol):
    def run_iteration(self, worker: Worker, plan: IterationPlan) -> float:
        """Execute (or simulate) one iteration; return its duration in
        seconds of the driving clock."""
        ...

    def on_finish(self, req: Request) -> None:
        """Request left the cluster (finished, or restarting from scratch
        after KV loss): release any per-request execution state."""
        ...

    def on_migrate(self, req: Request, src_wid: int, dst_wid: int) -> None:
        """The request's KV just crossed the links: materialise it on the
        destination so decode can continue there."""
        ...


class CostModelBackend:
    """Pure simulation: durations from the worker's analytical cost model."""

    def run_iteration(self, worker: Worker, plan: IterationPlan) -> float:
        return worker.plan_duration(plan)

    def on_finish(self, req: Request) -> None:
        pass

    def on_migrate(self, req: Request, src_wid: int, dst_wid: int) -> None:
        pass


class CallableBackend:
    """Wrap a bare ``duration_fn(worker, plan) -> seconds``."""

    def __init__(self, duration_fn: Callable[[Worker, IterationPlan], float],
                 base: ExecutionBackend | None = None):
        self.duration_fn = duration_fn
        # lifecycle hooks forward to the backend being wrapped (if any), so
        # ``sim.duration_fn = noisy_fn`` layered over a real backend keeps
        # slot teardown/migration working
        self.base = base

    def run_iteration(self, worker: Worker, plan: IterationPlan) -> float:
        return self.duration_fn(worker, plan)

    def on_finish(self, req: Request) -> None:
        if self.base is not None:
            self.base.on_finish(req)

    def on_migrate(self, req: Request, src_wid: int, dst_wid: int) -> None:
        if self.base is not None:
            self.base.on_migrate(req, src_wid, dst_wid)


class TraceReplayBackend:
    """Replay a trace through the scheduler without materialising it.

    Wraps the ``(arrival_time, Request)`` iterator contract of
    ``repro.workload.Scenario.replay`` / ``replay_csv`` (or any recorded
    stream in that shape) and an inner ``ExecutionBackend`` that supplies
    iteration durations (default: the analytical cost-model clock). The
    driver (``Simulator.add_replay``) pulls arrivals one at a time via
    ``next_arrival`` and keeps exactly one pending arrival event in its
    heap — a million-request production dump replays in constant memory,
    and the scheduling decisions are identical to pre-materialising the
    same stream with ``add_trace`` for time-sorted feeds with distinct
    timestamps (an arrival landing on exactly the same float second as
    another pending event tie-breaks by heap insertion order, which
    necessarily differs between the two feeds; continuous-time arrival
    processes never tie). Unsorted feeds raise ``ValueError``.
    """

    def __init__(self, replay: Iterable[tuple[float, Request]],
                 inner: Optional[ExecutionBackend] = None):
        self._iter: Iterator[tuple[float, Request]] = iter(replay)
        # remember whether the clock was defaulted: Simulator.add_replay
        # substitutes its configured backend for a defaulted inner, so a
        # pre-constructed TraceReplayBackend(feed) and a raw iterator get
        # the same physics (a custom duration_fn is never silently lost)
        self.inner_defaulted = inner is None
        self.inner: ExecutionBackend = inner or CostModelBackend()
        self.replayed = 0
        self._last_t = float("-inf")

    # ------------------------------------------------------- arrival stream
    def next_arrival(self) -> Optional[tuple[float, Request]]:
        """The next ``(arrival_time, Request)`` pair, or None when the
        trace is exhausted. Streaming keeps only ONE pending arrival, so
        the feed must be sorted by arrival time — an out-of-order item
        would move the driver's clock backwards and silently corrupt
        every now-derived metric. Raises ValueError instead (sort the
        trace, or use the materialising ``add_trace`` path, which heaps
        everything up front and tolerates any order)."""
        item = next(self._iter, None)
        if item is not None:
            if item[0] < self._last_t:
                raise ValueError(
                    f"trace-replay feed is not sorted by arrival time: "
                    f"got t={item[0]:.6f} after t={self._last_t:.6f} "
                    f"(rid={item[1].rid}); sort the trace or replay it "
                    f"via add_trace")
            self._last_t = item[0]
            self.replayed += 1
        return item

    # --------------------------------------------------- ExecutionBackend
    def run_iteration(self, worker: Worker, plan: IterationPlan) -> float:
        return self.inner.run_iteration(worker, plan)

    def on_finish(self, req: Request) -> None:
        self.inner.on_finish(req)

    def on_migrate(self, req: Request, src_wid: int, dst_wid: int) -> None:
        self.inner.on_migrate(req, src_wid, dst_wid)
