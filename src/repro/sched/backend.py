"""ExecutionBackend — the narrow seam between scheduling and execution.

The ``ClusterScheduler`` never runs compute and never reads a wall clock;
it asks its backend to execute one composed iteration and report how long
it took (simulated or measured), and notifies it of the few lifecycle
events an execution substrate must mirror (request teardown, KV
migration). Everything else — dispatch, queueing, routing, role changes —
is backend-agnostic scheduler code.

Implementations:

* ``CostModelBackend`` — the analytical roofline clock (discrete-event
  simulation; default).
* ``CallableBackend`` — adapts a bare ``duration_fn(worker, plan)`` (the
  legacy ``Simulator.duration_fn`` hook, noise-injection experiments).
* ``RealJaxBackend`` (serving/executor.py) — actually runs the JAX model
  and measures wall-clock, or runs it under the cost-model clock for
  decision-parity tests against the simulator.
"""
from __future__ import annotations

from typing import Callable, Protocol, runtime_checkable

from repro.core.request import Request
from repro.serving.engine import IterationPlan, Worker


@runtime_checkable
class ExecutionBackend(Protocol):
    def run_iteration(self, worker: Worker, plan: IterationPlan) -> float:
        """Execute (or simulate) one iteration; return its duration in
        seconds of the driving clock."""
        ...

    def on_finish(self, req: Request) -> None:
        """Request left the cluster (finished, or restarting from scratch
        after KV loss): release any per-request execution state."""
        ...

    def on_migrate(self, req: Request, src_wid: int, dst_wid: int) -> None:
        """The request's KV just crossed the links: materialise it on the
        destination so decode can continue there."""
        ...


class CostModelBackend:
    """Pure simulation: durations from the worker's analytical cost model."""

    def run_iteration(self, worker: Worker, plan: IterationPlan) -> float:
        return worker.plan_duration(plan)

    def on_finish(self, req: Request) -> None:
        pass

    def on_migrate(self, req: Request, src_wid: int, dst_wid: int) -> None:
        pass


class CallableBackend:
    """Wrap a bare ``duration_fn(worker, plan) -> seconds``."""

    def __init__(self, duration_fn: Callable[[Worker, IterationPlan], float],
                 base: ExecutionBackend | None = None):
        self.duration_fn = duration_fn
        # lifecycle hooks forward to the backend being wrapped (if any), so
        # ``sim.duration_fn = noisy_fn`` layered over a real backend keeps
        # slot teardown/migration working
        self.base = base

    def run_iteration(self, worker: Worker, plan: IterationPlan) -> float:
        return self.duration_fn(worker, plan)

    def on_finish(self, req: Request) -> None:
        if self.base is not None:
            self.base.on_finish(req)

    def on_migrate(self, req: Request, src_wid: int, dst_wid: int) -> None:
        if self.base is not None:
            self.base.on_migrate(req, src_wid, dst_wid)
